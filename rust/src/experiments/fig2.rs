//! Figure 2 (all five rows): the IL model can be small, trained
//! without holdout data, and reused across target architectures and
//! hyperparameters.
//!
//! Speedup metric, as in the paper: epochs by which RHO-LOSS first
//! exceeds the highest accuracy uniform reaches within the budget
//! ("epochs saved" = budget - rho_epochs; also reported as a ratio).

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::metrics::mean_curve;
use crate::experiments::common::Lab;
use crate::experiments::report::{pct, Table};
use crate::experiments::ExpCtx;
use crate::selection::Method;

/// Row 4's seven target architectures (paper: VGG11, GoogleNet,
/// ResNet34/50, DenseNet121, MobileNet-v2, Inception-v3).
const SEVEN_ARCHS: &[&str] =
    &["logreg", "mlp_small", "mlp_base", "mlp_wide", "mlp_deep", "cnn_small", "cnn_base"];

struct RowResult {
    label: String,
    uniform_best: f32,
    rho_epochs: Option<f64>,
    budget: usize,
    rho_final: f32,
}

fn run_pair(
    lab: &Lab,
    ctx: &ExpCtx,
    cfg: &RunConfig,
    label: &str,
) -> Result<RowResult> {
    let bundle = lab.bundle(&cfg.dataset);
    let mut uni_cfg = cfg.clone();
    uni_cfg.method = Method::Uniform;
    let uni_runs = lab.run_seeds(&uni_cfg, &bundle, &ctx.seeds)?;
    let uni = mean_curve(&uni_runs.iter().map(|r| r.curve.clone()).collect::<Vec<_>>());
    let mut rho_cfg = cfg.clone();
    rho_cfg.method = Method::RhoLoss;
    let rho_runs = lab.run_seeds(&rho_cfg, &bundle, &ctx.seeds)?;
    let rho = mean_curve(&rho_runs.iter().map(|r| r.curve.clone()).collect::<Vec<_>>());
    Ok(RowResult {
        label: label.to_string(),
        uniform_best: uni.best_accuracy(),
        rho_epochs: rho.epochs_to(uni.best_accuracy()),
        budget: cfg.epochs,
        rho_final: rho.final_accuracy(),
    })
}

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let lab = Lab::new(ctx)?;
    let out = ctx.out_dir("fig2")?;
    let mut table = Table::new(
        "Fig 2: IL-model robustness (speedup = epochs saved reaching uniform-best)",
        &["row", "config", "uniform best", "rho epochs", "epochs saved", "rho final"],
    );
    let mut rows: Vec<(&str, RowResult)> = Vec::new();

    let base = |dataset: &str, epochs: usize| RunConfig {
        dataset: dataset.into(),
        arch: "mlp_base".into(),
        il_arch: "mlp_small".into(),
        epochs: ctx.epochs(epochs),
        il_epochs: 10,
        ..Default::default()
    };

    // Row 1: IL model = same (large) arch as the target.
    for ds in ["cifar10", "cifar100"] {
        let mut cfg = base(ds, 20);
        cfg.il_arch = "mlp_base".into();
        rows.push(("1: large IL (same arch)", run_pair(&lab, ctx, &cfg, ds)?));
    }
    // Row 2: small, cheap IL model.
    for ds in ["cifar10", "cifar100", "cinic10"] {
        let cfg = base(ds, 20);
        rows.push(("2: small IL", run_pair(&lab, ctx, &cfg, ds)?));
    }
    // Row 3: no holdout data (two-model cross scheme).
    for ds in ["cifar10", "cifar100"] {
        let mut cfg = base(ds, 20);
        cfg.no_holdout = true;
        rows.push(("3: no holdout", run_pair(&lab, ctx, &cfg, ds)?));
    }
    // Row 4: one small IL model, seven target architectures.
    for arch in SEVEN_ARCHS {
        let mut cfg = base("cifar10", 16);
        cfg.arch = arch.to_string();
        rows.push(("4: target arch", run_pair(&lab, ctx, &cfg, arch)?));
    }
    // Row 5: hyperparameter grid (lr x wd at nb=32, plus nb variants).
    for lr in [1e-4f32, 1e-3, 1e-2] {
        for wd in [1e-3f32, 1e-2, 1e-1] {
            let mut cfg = base("cifar10", 12);
            cfg.lr = lr;
            cfg.wd = wd;
            let label = format!("lr={lr:.0e} wd={wd:.0e}");
            rows.push(("5: hyperparams", run_pair(&lab, ctx, &cfg, &label)?));
        }
    }
    for nb in [16usize, 64] {
        let mut cfg = base("cifar10", 12);
        cfg.nb = nb;
        let label = format!("nb={nb}");
        rows.push(("5: hyperparams", run_pair(&lab, ctx, &cfg, &label)?));
    }

    let mut positive = 0;
    let total = rows.len();
    for (row, r) in &rows {
        let saved = r.rho_epochs.map(|e| r.budget as f64 - e);
        if saved.map(|s| s > 0.0).unwrap_or(false) {
            positive += 1;
        }
        table.row(vec![
            row.to_string(),
            r.label.clone(),
            pct(r.uniform_best),
            r.rho_epochs.map(|e| format!("{e:.1}")).unwrap_or("NR".into()),
            saved.map(|s| format!("{s:.1}")).unwrap_or("-".into()),
            pct(r.rho_final),
        ]);
    }
    table.emit(&out, "fig2")?;
    println!(
        "rho reached uniform-best early in {positive}/{total} configs \
         (paper: speedups on nearly all dots, incl. small/no-holdout/reused IL)"
    );
    Ok(())
}
