//! Table 3 (App. A): RHO-LOSS without ANY holdout data — two IL models
//! each trained on half the train set, cross-scoring the other half —
//! versus uniform. Epochs to anchored targets + final accuracy.

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::metrics::{fmt_epochs, mean_curve};
use crate::experiments::common::{anchored_target, Lab};
use crate::experiments::report::{pct, Table};
use crate::experiments::ExpCtx;
use crate::selection::Method;

const ROWS: &[(&str, usize)] = &[("cifar10", 25), ("cifar100", 30), ("cinic10", 15)];

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let lab = Lab::new(ctx)?;
    let out = ctx.out_dir("table3")?;
    let mut table = Table::new(
        "Table 3: no-holdout RHO-LOSS (two-model cross scheme)",
        &["dataset", "target", "uniform", "rho_loss (no holdout)"],
    );

    for &(dataset, epochs) in ROWS {
        let bundle = lab.bundle(dataset);
        let mut cfg = RunConfig {
            dataset: dataset.into(),
            arch: if dataset.starts_with("cinic") { "cnn_small" } else { "mlp_base" }.into(),
            il_arch: "mlp_small".into(),
            epochs: ctx.epochs(epochs),
            il_epochs: 10,
            no_holdout: true,
            method: Method::Uniform,
            ..Default::default()
        };
        let uni_runs = lab.run_seeds(&cfg, &bundle, &ctx.seeds)?;
        let uni = mean_curve(&uni_runs.iter().map(|r| r.curve.clone()).collect::<Vec<_>>());
        cfg.method = Method::RhoLoss;
        let rho_runs = lab.run_seeds(&cfg, &bundle, &ctx.seeds)?;
        let rho = mean_curve(&rho_runs.iter().map(|r| r.curve.clone()).collect::<Vec<_>>());
        uni.write_csv(&out.join(format!("curve_{dataset}_uniform.csv")))?;
        rho.write_csv(&out.join(format!("curve_{dataset}_rho.csv")))?;

        let classes = bundle.train.classes;
        for (ti, frac) in [0.80f32, 0.97].iter().enumerate() {
            let target = anchored_target(classes, uni.best_accuracy(), *frac);
            let fmt = |c: &crate::coordinator::metrics::Curve| {
                if ti == 1 {
                    format!("{} ({})", fmt_epochs(c.epochs_to(target)), pct(c.final_accuracy()))
                } else {
                    fmt_epochs(c.epochs_to(target))
                }
            };
            table.row(vec![
                if ti == 0 { dataset.into() } else { String::new() },
                pct(target),
                fmt(&uni),
                fmt(&rho),
            ]);
        }
    }
    table.emit(&out, "table3")?;
    println!("(paper: no-holdout RHO still beats uniform on every dataset)");
    Ok(())
}
