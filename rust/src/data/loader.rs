//! Epoch streaming: shuffle-without-replacement candidate batches.
//!
//! This is the "online batch selection" data feed (paper §2): each
//! step draws a large batch `B_t` of `n_B` indices without replacement;
//! replacement happens when the next epoch starts (random shuffling).

use crate::util::rng::Pcg32;

/// Streams candidate-batch index slices over a dataset, reshuffling at
/// every epoch boundary.
pub struct EpochSampler {
    order: Vec<u32>,
    pos: usize,
    pub epoch: usize,
    rng: Pcg32,
}

impl EpochSampler {
    pub fn new(n: usize, seed: u64) -> Self {
        let mut rng = Pcg32::new(seed, 21);
        let mut order: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut order);
        EpochSampler { order, pos: 0, epoch: 0, rng }
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// Number of candidate batches per epoch for a given `n_b` batch
    /// size (the final partial batch counts).
    pub fn batches_per_epoch(&self, nb: usize) -> usize {
        self.order.len().div_ceil(nb)
    }

    /// Next candidate batch of up to `n` indices. Returns
    /// `(indices, epoch_rolled)`; `epoch_rolled` is true when this call
    /// crossed an epoch boundary (buffer reshuffled before serving).
    pub fn next_batch(&mut self, n: usize, out: &mut Vec<u32>) -> bool {
        out.clear();
        let mut rolled = false;
        if self.pos >= self.order.len() {
            self.rng.shuffle(&mut self.order);
            self.pos = 0;
            self.epoch += 1;
            rolled = true;
        }
        let take = n.min(self.order.len() - self.pos);
        out.extend_from_slice(&self.order[self.pos..self.pos + take]);
        self.pos += take;
        rolled
    }

    /// Like [`next_batch`](Self::next_batch), but returns an owned
    /// index buffer: the streaming engine's producer moves it straight
    /// into the candidate batch instead of cloning a reusable buffer.
    pub fn take_batch(&mut self, n: usize) -> (Vec<u32>, bool) {
        let mut idx = Vec::with_capacity(n.min(self.order.len()));
        let rolled = self.next_batch(n, &mut idx);
        (idx, rolled)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use std::collections::HashSet;

    #[test]
    fn covers_every_point_each_epoch_prop() {
        prop::check("epoch-coverage", 25, |rng| {
            let n = 10 + rng.below(500);
            let nb = 1 + rng.below(64);
            let mut s = EpochSampler::new(n, rng.next_u64());
            let mut seen = HashSet::new();
            let mut buf = Vec::new();
            // first epoch: batches until just before the roll
            for _ in 0..s.batches_per_epoch(nb) {
                let rolled = s.next_batch(nb, &mut buf);
                if rolled {
                    return Err("rolled before epoch should end".into());
                }
                for &i in &buf {
                    if !seen.insert(i) {
                        return Err(format!("index {i} served twice in one epoch"));
                    }
                }
            }
            if seen.len() != n {
                return Err(format!("served {} of {n} points", seen.len()));
            }
            // next call rolls the epoch
            let rolled = s.next_batch(nb, &mut buf);
            if !rolled || s.epoch != 1 {
                return Err("expected epoch roll".into());
            }
            Ok(())
        });
    }

    #[test]
    fn reshuffles_between_epochs() {
        let mut s = EpochSampler::new(1000, 3);
        let mut buf = Vec::new();
        s.next_batch(1000, &mut buf);
        let first = buf.clone();
        s.next_batch(1000, &mut buf);
        assert_eq!(buf.len(), 1000);
        assert_ne!(first, buf, "order identical across epochs");
    }

    #[test]
    fn partial_final_batch() {
        let mut s = EpochSampler::new(10, 4);
        let mut buf = Vec::new();
        s.next_batch(4, &mut buf);
        s.next_batch(4, &mut buf);
        s.next_batch(4, &mut buf);
        assert_eq!(buf.len(), 2, "final partial batch should have 2");
    }

    #[test]
    fn take_batch_matches_next_batch() {
        let mut a = EpochSampler::new(50, 4);
        let mut b = EpochSampler::new(50, 4);
        let mut buf = Vec::new();
        for _ in 0..20 {
            let rolled_a = a.next_batch(7, &mut buf);
            let (idx, rolled_b) = b.take_batch(7);
            assert_eq!(buf, idx);
            assert_eq!(rolled_a, rolled_b);
        }
    }

    #[test]
    fn deterministic_with_seed() {
        let mut a = EpochSampler::new(100, 9);
        let mut b = EpochSampler::new(100, 9);
        let (mut ba, mut bb) = (Vec::new(), Vec::new());
        for _ in 0..30 {
            a.next_batch(7, &mut ba);
            b.next_batch(7, &mut bb);
            assert_eq!(ba, bb);
        }
    }
}
