//! Epoch streaming: shuffle-without-replacement candidate batches.
//!
//! This is the "online batch selection" data feed (paper §2): each
//! step draws a large batch `B_t` of `n_B` indices without replacement;
//! replacement happens when the next epoch starts (random shuffling).
//!
//! Two samplers share those semantics:
//!
//! - [`EpochSampler`] — the original dense sampler: one global
//!   Fisher-Yates permutation per epoch. Right when the whole dataset
//!   sits in memory.
//! - [`StreamSampler`] — the two-level sampler the engine uses for
//!   *sharded* sources (and, degenerately, for in-memory ones): per
//!   epoch it shuffles the **shard order**, then shuffles rows within
//!   a bounded **window** of the resulting stream. A row is never
//!   displaced more than `window` positions from its shard-stream
//!   slot, so a reader only ever needs the shards overlapping the
//!   current window resident — that bounded locality is what makes
//!   larger-than-memory stores streamable. With a single shard and a
//!   full-dataset window it draws the *bit-identical* first-epoch
//!   permutation `EpochSampler` draws (same RNG stream), and every
//!   epoch is generated fresh from the epoch-start RNG state, so a
//!   [`SamplerCursor`] (epoch, position, epoch-start state) is a
//!   complete, O(n)-restorable checkpoint of the stream — that cursor
//!   is what `SessionCheckpoint` serializes.

use anyhow::{bail, Result};

use crate::util::rng::Pcg32;

/// Row-count layout of a (possibly sharded) data source: how many rows
/// each storage block holds, in storage order. The sampler only needs
/// the layout — not the data — so an in-memory dataset can sample with
/// the *same* stream semantics as a shard directory by declaring the
/// same layout (the memory-vs-shards bitwise-parity contract).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardLayout {
    blocks: Vec<u32>,
}

impl ShardLayout {
    /// One block covering the whole set (dense in-memory layout).
    pub fn single(n: usize) -> ShardLayout {
        ShardLayout { blocks: vec![n as u32] }
    }

    /// Chunk `n` rows into `shard_rows`-sized blocks (ragged tail kept);
    /// `shard_rows == 0` means a single block.
    pub fn chunked(n: usize, shard_rows: usize) -> ShardLayout {
        if shard_rows == 0 || shard_rows >= n {
            return ShardLayout::single(n);
        }
        let mut blocks = Vec::with_capacity(n.div_ceil(shard_rows));
        let mut left = n;
        while left > 0 {
            let take = left.min(shard_rows);
            blocks.push(take as u32);
            left -= take;
        }
        ShardLayout { blocks }
    }

    /// Layout from explicit per-block row counts (a shard directory).
    pub fn from_blocks(blocks: Vec<u32>) -> ShardLayout {
        assert!(!blocks.is_empty(), "layout needs at least one block");
        ShardLayout { blocks }
    }

    pub fn total(&self) -> usize {
        self.blocks.iter().map(|&b| b as usize).sum()
    }

    pub fn blocks(&self) -> &[u32] {
        &self.blocks
    }

    /// Block (shard) index containing global `row`. Linear scan over
    /// the block table — callers on hot paths (remote prefetch and
    /// windowed eviction) hold their own cumulative-start tables; this
    /// is the convenience form for tests and one-off lookups.
    pub fn block_of(&self, row: u32) -> usize {
        let mut start = 0u64;
        for (i, &b) in self.blocks.iter().enumerate() {
            start += b as u64;
            if (row as u64) < start {
                return i;
            }
        }
        self.blocks.len() - 1
    }

    /// Stable fingerprint of the block structure (XXH64 over the LE
    /// block sizes). Serialized into session checkpoints so resuming
    /// under a different layout (changed `shard_rows`, different
    /// store, memory↔shards swap with equal `n`) is a hard error —
    /// the index stream would silently diverge otherwise.
    pub fn fingerprint(&self) -> u64 {
        let mut bytes = Vec::with_capacity(self.blocks.len() * 4);
        for &b in &self.blocks {
            bytes.extend_from_slice(&b.to_le_bytes());
        }
        crate::util::hash::xxh64(&bytes, 0x5AD0_11AE)
    }
}

/// Resumable position of a [`StreamSampler`]: the epoch index, the
/// row position within the epoch, and the PCG32 state captured at the
/// *start* of the epoch's order generation. Restoring replays only the
/// current epoch's (gather-free) order generation — O(n) swaps — then
/// seeks to `pos`; the RNG lands exactly where the saved run left it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SamplerCursor {
    pub epoch: u64,
    pub pos: u64,
    /// `Pcg32::state()` at the start of the current epoch.
    pub rng: (u64, u64),
}

/// Streams candidate-batch index slices over a dataset, reshuffling at
/// every epoch boundary.
pub struct EpochSampler {
    order: Vec<u32>,
    pos: usize,
    pub epoch: usize,
    rng: Pcg32,
}

impl EpochSampler {
    pub fn new(n: usize, seed: u64) -> Self {
        let mut rng = Pcg32::new(seed, 21);
        let mut order: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut order);
        EpochSampler { order, pos: 0, epoch: 0, rng }
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// Number of candidate batches per epoch for a given `n_b` batch
    /// size (the final partial batch counts).
    pub fn batches_per_epoch(&self, nb: usize) -> usize {
        self.order.len().div_ceil(nb)
    }

    /// Next candidate batch of up to `n` indices. Returns
    /// `(indices, epoch_rolled)`; `epoch_rolled` is true when this call
    /// crossed an epoch boundary (buffer reshuffled before serving).
    pub fn next_batch(&mut self, n: usize, out: &mut Vec<u32>) -> bool {
        out.clear();
        let mut rolled = false;
        if self.pos >= self.order.len() {
            self.rng.shuffle(&mut self.order);
            self.pos = 0;
            self.epoch += 1;
            rolled = true;
        }
        let take = n.min(self.order.len() - self.pos);
        out.extend_from_slice(&self.order[self.pos..self.pos + take]);
        self.pos += take;
        rolled
    }

    /// Like [`next_batch`](Self::next_batch), but returns an owned
    /// index buffer: the streaming engine's producer moves it straight
    /// into the candidate batch instead of cloning a reusable buffer.
    pub fn take_batch(&mut self, n: usize) -> (Vec<u32>, bool) {
        let mut idx = Vec::with_capacity(n.min(self.order.len()));
        let rolled = self.next_batch(n, &mut idx);
        (idx, rolled)
    }
}

/// Two-level streaming sampler over a [`ShardLayout`] (see module
/// docs): per epoch, shuffle shard order, then shuffle rows within
/// bounded windows of the shard stream. Deterministic under `Pcg32`
/// and checkpointable via [`SamplerCursor`].
pub struct StreamSampler {
    layout: ShardLayout,
    /// Effective shuffle-window size in rows (>= 1, <= n).
    window: usize,
    order: Vec<u32>,
    pos: usize,
    pub epoch: usize,
    rng: Pcg32,
    /// RNG state at the start of the current epoch (cursor anchor).
    epoch_rng: (u64, u64),
}

impl StreamSampler {
    /// `window == 0` means a full-epoch window (global shuffle). The
    /// RNG stream id matches [`EpochSampler`]'s, so the degenerate
    /// single-block + full-window configuration reproduces its first
    /// epoch bit for bit.
    pub fn new(layout: ShardLayout, window: usize, seed: u64) -> StreamSampler {
        let n = layout.total();
        assert!(n > 0, "empty layout");
        let window = if window == 0 { n } else { window.min(n) };
        let mut s = StreamSampler {
            layout,
            window,
            order: Vec::with_capacity(n),
            pos: 0,
            epoch: 0,
            rng: Pcg32::new(seed, 21),
            epoch_rng: (0, 0),
        };
        s.gen_epoch_order();
        s
    }

    /// Regenerate `order` for the current epoch from the current RNG
    /// state: shard-order shuffle, then windowed row shuffle. Always
    /// starts from the identity shard stream, so the epoch is a pure
    /// function of `(layout, window, epoch-start RNG state)` — the
    /// property cursor restore relies on.
    fn gen_epoch_order(&mut self) {
        self.epoch_rng = self.rng.state();
        let mut block_ids: Vec<u32> = (0..self.layout.blocks.len() as u32).collect();
        self.rng.shuffle(&mut block_ids);
        // block start offsets in storage order
        let mut starts = Vec::with_capacity(self.layout.blocks.len());
        let mut acc = 0u32;
        for &b in &self.layout.blocks {
            starts.push(acc);
            acc += b;
        }
        self.order.clear();
        for &b in &block_ids {
            let (start, len) = (starts[b as usize], self.layout.blocks[b as usize]);
            self.order.extend(start..start + len);
        }
        for chunk in self.order.chunks_mut(self.window) {
            self.rng.shuffle(chunk);
        }
        self.pos = 0;
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    pub fn batches_per_epoch(&self, nb: usize) -> usize {
        self.order.len().div_ceil(nb)
    }

    /// Next candidate batch of up to `n` indices; `true` when this
    /// call crossed an epoch boundary (same contract as
    /// [`EpochSampler::next_batch`]).
    pub fn next_batch(&mut self, n: usize, out: &mut Vec<u32>) -> bool {
        out.clear();
        let mut rolled = false;
        if self.pos >= self.order.len() {
            self.epoch += 1;
            self.gen_epoch_order();
            rolled = true;
        }
        let take = n.min(self.order.len() - self.pos);
        out.extend_from_slice(&self.order[self.pos..self.pos + take]);
        self.pos += take;
        rolled
    }

    /// Owned-buffer variant for the engine's producer (see
    /// [`EpochSampler::take_batch`]).
    pub fn take_batch(&mut self, n: usize) -> (Vec<u32>, bool) {
        let mut idx = Vec::with_capacity(n.min(self.order.len()));
        let rolled = self.next_batch(n, &mut idx);
        (idx, rolled)
    }

    /// Effective shuffle-window size in rows (`n` when constructed
    /// with `window == 0`). A full-epoch window means accesses are
    /// uniform over the whole set — prefetch hints carry no locality
    /// then, which is why the engine only hints in windowed mode.
    pub fn window(&self) -> usize {
        self.window
    }

    /// The not-yet-served tail of the current shuffle window — the rows
    /// a prefetcher should have resident next. (Bounded: at most
    /// `window` rows.)
    pub fn upcoming(&self) -> &[u32] {
        let hi = (self.pos + self.window).min(self.order.len());
        &self.order[self.pos..hi]
    }

    /// Checkpointable stream position (see [`SamplerCursor`]).
    pub fn cursor(&self) -> SamplerCursor {
        SamplerCursor { epoch: self.epoch as u64, pos: self.pos as u64, rng: self.epoch_rng }
    }

    /// Restore a cursor saved by [`cursor`](Self::cursor) on a sampler
    /// built over the *same* layout, window, and seed: re-seeds the RNG
    /// to the cursor's epoch-start state, regenerates that epoch's
    /// order, and seeks to the saved position. The continuation is
    /// bitwise-identical to the uninterrupted stream.
    pub fn restore(&mut self, cur: SamplerCursor) -> Result<()> {
        if cur.pos as usize > self.order.len() {
            bail!(
                "sampler cursor position {} exceeds epoch length {} (layout mismatch?)",
                cur.pos,
                self.order.len()
            );
        }
        self.rng = Pcg32::from_state(cur.rng);
        self.epoch = cur.epoch as usize;
        self.gen_epoch_order();
        self.pos = cur.pos as usize;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use std::collections::HashSet;

    #[test]
    fn block_of_maps_rows_to_their_shard() {
        let l = ShardLayout::from_blocks(vec![4, 4, 2]);
        assert_eq!(l.block_of(0), 0);
        assert_eq!(l.block_of(3), 0);
        assert_eq!(l.block_of(4), 1);
        assert_eq!(l.block_of(8), 2);
        assert_eq!(l.block_of(9), 2);
    }

    #[test]
    fn covers_every_point_each_epoch_prop() {
        prop::check("epoch-coverage", 25, |rng| {
            let n = 10 + rng.below(500);
            let nb = 1 + rng.below(64);
            let mut s = EpochSampler::new(n, rng.next_u64());
            let mut seen = HashSet::new();
            let mut buf = Vec::new();
            // first epoch: batches until just before the roll
            for _ in 0..s.batches_per_epoch(nb) {
                let rolled = s.next_batch(nb, &mut buf);
                if rolled {
                    return Err("rolled before epoch should end".into());
                }
                for &i in &buf {
                    if !seen.insert(i) {
                        return Err(format!("index {i} served twice in one epoch"));
                    }
                }
            }
            if seen.len() != n {
                return Err(format!("served {} of {n} points", seen.len()));
            }
            // next call rolls the epoch
            let rolled = s.next_batch(nb, &mut buf);
            if !rolled || s.epoch != 1 {
                return Err("expected epoch roll".into());
            }
            Ok(())
        });
    }

    #[test]
    fn reshuffles_between_epochs() {
        let mut s = EpochSampler::new(1000, 3);
        let mut buf = Vec::new();
        s.next_batch(1000, &mut buf);
        let first = buf.clone();
        s.next_batch(1000, &mut buf);
        assert_eq!(buf.len(), 1000);
        assert_ne!(first, buf, "order identical across epochs");
    }

    #[test]
    fn partial_final_batch() {
        let mut s = EpochSampler::new(10, 4);
        let mut buf = Vec::new();
        s.next_batch(4, &mut buf);
        s.next_batch(4, &mut buf);
        s.next_batch(4, &mut buf);
        assert_eq!(buf.len(), 2, "final partial batch should have 2");
    }

    #[test]
    fn take_batch_matches_next_batch() {
        let mut a = EpochSampler::new(50, 4);
        let mut b = EpochSampler::new(50, 4);
        let mut buf = Vec::new();
        for _ in 0..20 {
            let rolled_a = a.next_batch(7, &mut buf);
            let (idx, rolled_b) = b.take_batch(7);
            assert_eq!(buf, idx);
            assert_eq!(rolled_a, rolled_b);
        }
    }

    #[test]
    fn deterministic_with_seed() {
        let mut a = EpochSampler::new(100, 9);
        let mut b = EpochSampler::new(100, 9);
        let (mut ba, mut bb) = (Vec::new(), Vec::new());
        for _ in 0..30 {
            a.next_batch(7, &mut ba);
            b.next_batch(7, &mut bb);
            assert_eq!(ba, bb);
        }
    }

    // ---- StreamSampler -------------------------------------------------

    #[test]
    fn chunked_layout_shapes() {
        assert_eq!(ShardLayout::single(10).blocks(), &[10]);
        assert_eq!(ShardLayout::chunked(10, 0).blocks(), &[10]);
        assert_eq!(ShardLayout::chunked(10, 4).blocks(), &[4, 4, 2]);
        assert_eq!(ShardLayout::chunked(8, 4).blocks(), &[4, 4]);
        assert_eq!(ShardLayout::chunked(3, 4).blocks(), &[3]);
        assert_eq!(ShardLayout::chunked(10, 4).total(), 10);
    }

    #[test]
    fn degenerate_stream_matches_epoch_sampler_first_epoch() {
        // Single block + full window must reproduce EpochSampler's
        // first-epoch permutation bit for bit (same RNG stream) — this
        // is what keeps default in-memory runs on the engine's new
        // sampler identical to the old one within an epoch.
        let (n, seed) = (137usize, 0xBA7Cu64);
        let mut dense = EpochSampler::new(n, seed);
        let mut stream = StreamSampler::new(ShardLayout::single(n), 0, seed);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        for _ in 0..dense.batches_per_epoch(13) {
            let ra = dense.next_batch(13, &mut a);
            let rb = stream.next_batch(13, &mut b);
            assert_eq!(a, b, "index stream diverged");
            assert_eq!(ra, rb);
        }
    }

    #[test]
    fn stream_covers_every_point_each_epoch_prop() {
        prop::check("stream-epoch-coverage", 25, |rng| {
            let n = 10 + rng.below(400);
            let shard_rows = 1 + rng.below(n);
            let window = 1 + rng.below(2 * n);
            let nb = 1 + rng.below(48);
            let mut s =
                StreamSampler::new(ShardLayout::chunked(n, shard_rows), window, rng.next_u64());
            let mut buf = Vec::new();
            // two full epochs: every point exactly once per epoch
            for epoch in 0..2 {
                let mut seen = HashSet::new();
                for batch in 0..s.batches_per_epoch(nb) {
                    let rolled = s.next_batch(nb, &mut buf);
                    if rolled != (epoch > 0 && batch == 0) {
                        return Err(format!("unexpected roll at epoch {epoch} batch {batch}"));
                    }
                    for &i in &buf {
                        if i as usize >= n || !seen.insert(i) {
                            return Err(format!("bad/duplicate index {i} in epoch {epoch}"));
                        }
                    }
                }
                if seen.len() != n {
                    return Err(format!("epoch {epoch} served {} of {n}", seen.len()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn window_bounds_row_displacement() {
        // A row may move at most `window` positions from its slot in
        // the shuffled shard stream — the bounded-locality guarantee a
        // prefetching reader relies on.
        prop::check("stream-window-bound", 20, |rng| {
            let n = 50 + rng.below(300);
            let shard_rows = 1 + rng.below(n);
            let window = 1 + rng.below(n);
            let seed = rng.next_u64();
            let layout = ShardLayout::chunked(n, shard_rows);
            let mut s = StreamSampler::new(layout.clone(), window, seed);
            // reconstruct the pre-window-shuffle stream with the same RNG
            let mut check_rng = Pcg32::new(seed, 21);
            let mut block_ids: Vec<u32> = (0..layout.blocks().len() as u32).collect();
            check_rng.shuffle(&mut block_ids);
            let mut starts = vec![0u32];
            for &b in layout.blocks() {
                starts.push(starts.last().unwrap() + b);
            }
            let mut stream_pos = vec![0usize; n];
            let mut p = 0usize;
            for &b in &block_ids {
                for r in starts[b as usize]..starts[b as usize] + layout.blocks()[b as usize] {
                    stream_pos[r as usize] = p;
                    p += 1;
                }
            }
            let mut buf = Vec::new();
            let mut final_pos = vec![0usize; n];
            let mut at = 0usize;
            for _ in 0..s.batches_per_epoch(32) {
                s.next_batch(32, &mut buf);
                for &i in &buf {
                    final_pos[i as usize] = at;
                    at += 1;
                }
            }
            for i in 0..n {
                let d = final_pos[i].abs_diff(stream_pos[i]);
                if d >= window {
                    return Err(format!(
                        "row {i} displaced {d} >= window {window} (n {n}, shard_rows {shard_rows})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cursor_restore_continues_bitwise() {
        prop::check("stream-cursor-restore", 20, |rng| {
            let n = 20 + rng.below(300);
            let shard_rows = 1 + rng.below(n);
            let window = 1 + rng.below(n);
            let nb = 1 + rng.below(40);
            let seed = rng.next_u64();
            let layout = ShardLayout::chunked(n, shard_rows);
            let mut a = StreamSampler::new(layout.clone(), window, seed);
            // run anywhere into the second epoch (exercises mid-shard,
            // mid-window, and post-roll cursors)
            let steps = 1 + rng.below(2 * n.div_ceil(nb));
            let mut buf = Vec::new();
            for _ in 0..steps {
                a.next_batch(nb, &mut buf);
            }
            let cur = a.cursor();
            let mut b = StreamSampler::new(layout, window, seed);
            b.restore(cur).map_err(|e| e.to_string())?;
            if b.cursor() != cur {
                return Err("cursor did not round-trip".into());
            }
            let (mut ba, mut bb) = (Vec::new(), Vec::new());
            for _ in 0..(3 * n.div_ceil(nb)) {
                let ra = a.next_batch(nb, &mut ba);
                let rb = b.next_batch(nb, &mut bb);
                if ba != bb || ra != rb {
                    return Err("restored stream diverged".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn upcoming_is_bounded_by_window() {
        let mut s = StreamSampler::new(ShardLayout::chunked(100, 16), 24, 5);
        assert_eq!(s.upcoming().len(), 24);
        let mut buf = Vec::new();
        for _ in 0..s.batches_per_epoch(32) {
            s.next_batch(32, &mut buf);
            assert!(s.upcoming().len() <= 24);
        }
    }
}
