//! Named dataset builders: synthetic analogues of the paper's seven
//! benchmarks (+ CIFAR100-Relevance). Sizes are scaled for CPU budget;
//! the *selection-relevant* structure of each benchmark is preserved
//! (DESIGN.md §2 table).

use crate::data::noise;
use crate::data::synth::{Generator, SynthSpec};
use crate::data::Bundle;
use crate::util::rng::Pcg32;

/// Input dim for "vector" datasets (QMNIST/CoLA/SST-2 analogues).
pub const D_VEC: usize = 64;
/// Input dim for "image" datasets (16x16, CIFAR/CINIC/Clothing analogues).
pub const D_IMG: usize = 256;

/// All catalog names, in the order Table 2 reports them.
pub const ALL: &[&str] = &[
    "clothing1m",
    "cifar10",
    "cifar10_noise",
    "cifar100",
    "cifar100_noise",
    "cinic10",
    "cinic10_noise",
    "sst2",
    "cola",
    "qmnist",
    "cifar100_relevance",
];

/// Scale factor for dataset sizes: 1.0 = the default (CPU-friendly)
/// sizes; benches use < 1.0 for quick runs.
pub fn build(name: &str, seed: u64, scale: f64) -> Bundle {
    let s = |n: usize| ((n as f64 * scale).round() as usize).max(64);
    let mut rng = Pcg32::new(seed ^ 0xDA7A, 3);
    match name {
        // QMNIST: easy, clean, 10-class vector task; the 50k extra
        // QMNIST digits become a large holdout.
        "qmnist" => {
            let g = Generator::new(SynthSpec::vector(D_VEC, 10, 0.8), seed);
            bundle(name, &g, s(12_000), s(10_000), s(2_000), s(4_000), &mut rng)
        }
        // CIFAR-10: harder 10-class image task; paper trains on half,
        // IL model on the other half -> train == holdout size.
        "cifar10" => {
            let g = Generator::new(SynthSpec::image(D_IMG, 10, 0.95), seed);
            bundle(name, &g, s(10_000), s(10_000), s(2_000), s(4_000), &mut rng)
        }
        "cifar10_noise" => with_uniform_noise(build("cifar10", seed, scale), 0.1, seed),
        "cifar100" => {
            let g = Generator::new(SynthSpec::image(D_IMG, 100, 1.35), seed);
            bundle(name, &g, s(12_000), s(12_000), s(2_500), s(5_000), &mut rng)
        }
        "cifar100_noise" => with_uniform_noise(build("cifar100", seed, scale), 0.1, seed),
        // CINIC-10: 4.5x CIFAR-10's size, slightly dirtier distribution.
        "cinic10" => {
            let g = Generator::new(SynthSpec::image(D_IMG, 10, 0.85), seed);
            let mut b = bundle(name, &g, s(24_000), s(12_000), s(3_000), s(8_000), &mut rng);
            let mut nrng = Pcg32::new(seed ^ 0x0c1b, 9);
            noise::uniform_label_noise(&mut b.train, 0.03, &mut nrng);
            b
        }
        "cinic10_noise" => with_uniform_noise(build("cinic10", seed, scale), 0.1, seed),
        // Clothing-1M: web-scraped -> ~35% mixed label noise + 5x
        // duplication; IL model trains on a 10%-sized noisy draw;
        // clean test (Clothing-1M's test labels are curated).
        "clothing1m" => {
            let g = Generator::new(SynthSpec::image(D_IMG, 14, 1.1), seed);
            let base = s(6_000);
            let mut train = g.sample(base, &mut rng);
            let mut nrng = Pcg32::new(seed ^ 0xc107, 5);
            noise::uniform_label_noise(&mut train, 0.25, &mut nrng);
            let pairs = g.confusable_pairs(4);
            noise::structured_confusion_noise(&mut train, &pairs, 0.25, &mut nrng);
            noise::duplicate_to(&mut train, s(30_000), 0.08, &mut nrng);
            // Holdout: 10%-sized draw from the same noisy distribution.
            // (The paper reuses 10% of the 1M-image train set; at our
            // scale literal reuse lets the IL model *memorize* the
            // noisy labels, which the paper's underfit ResNet18 cannot
            // do on 100k images — a fresh noisy draw preserves the
            // intended behaviour. See DESIGN.md §2.)
            let mut holdout = g.sample(s(3_000), &mut rng);
            noise::uniform_label_noise(&mut holdout, 0.20, &mut nrng);
            noise::structured_confusion_noise(&mut holdout, &pairs, 0.25, &mut nrng);
            let val = g.sample(s(1_500), &mut rng);
            let test = g.sample(s(6_000), &mut rng);
            Bundle { name: name.into(), train, holdout, val, test }
        }
        // CoLA: small, binary, imbalanced (70/30), noisy labels — the
        // benchmark where the paper sees >10x speedups and unstable
        // uniform baselines.
        "cola" => {
            let mut spec = SynthSpec::vector(D_VEC, 2, 0.8);
            spec.class_weights = Some(vec![0.7, 0.3]);
            let g = Generator::new(spec, seed);
            let mut b = bundle(name, &g, s(4_000), s(4_000), s(800), s(1_000), &mut rng);
            let mut nrng = Pcg32::new(seed ^ 0xc01a, 7);
            noise::uniform_label_noise(&mut b.train, 0.08, &mut nrng);
            b
        }
        "sst2" => {
            let g = Generator::new(SynthSpec::vector(D_VEC, 2, 1.0), seed);
            let mut b = bundle(name, &g, s(8_000), s(8_000), s(1_000), s(2_000), &mut rng);
            let mut nrng = Pcg32::new(seed ^ 0x5512, 7);
            noise::uniform_label_noise(&mut b.train, 0.03, &mut nrng);
            b
        }
        // CIFAR100-Relevance: 80% of data from 20% of classes (Fig. 3
        // middle): keep all of 20 "high relevance" classes, 6% of rest.
        "cifar100_relevance" => {
            let g = Generator::new(SynthSpec::image(D_IMG, 100, 1.35), seed);
            let mut rrng = Pcg32::new(seed ^ 0x4e1e, 11);
            let high: Vec<u32> = rrng.choose_k(100, 20).into_iter().map(|i| i as u32).collect();
            let raw_train = g.sample(s(40_000), &mut rng);
            let train = noise::relevance_filter(&raw_train, &high, 0.06, &mut rrng);
            let raw_hold = g.sample(s(40_000), &mut rng);
            let holdout = noise::relevance_filter(&raw_hold, &high, 0.06, &mut rrng);
            let raw_val = g.sample(s(8_000), &mut rng);
            let val = noise::relevance_filter(&raw_val, &high, 0.06, &mut rrng);
            let raw_test = g.sample(s(16_000), &mut rng);
            let test = noise::relevance_filter(&raw_test, &high, 0.06, &mut rrng);
            Bundle { name: name.into(), train, holdout, val, test }
        }
        other => panic!("unknown dataset `{other}` (known: {ALL:?})"),
    }
}

/// Convenience: the paper's "+10% uniform label noise" variant of a
/// clean bundle (train split only; eval splits stay clean).
pub fn with_uniform_noise(mut b: Bundle, frac: f32, seed: u64) -> Bundle {
    let mut rng = Pcg32::new(seed ^ 0x401e, 13);
    noise::uniform_label_noise(&mut b.train, frac, &mut rng);
    b.name = format!("{}+noise{:.0}%", b.name.trim_end_matches("_noise"), frac * 100.0);
    b
}

fn bundle(
    name: &str,
    g: &Generator,
    n_train: usize,
    n_holdout: usize,
    n_val: usize,
    n_test: usize,
    rng: &mut Pcg32,
) -> Bundle {
    Bundle {
        name: name.into(),
        train: g.sample(n_train, rng),
        holdout: g.sample(n_holdout, rng),
        val: g.sample(n_val, rng),
        test: g.sample(n_test, rng),
    }
}

/// The generator behind a named dataset (needed by noise-robustness
/// experiments that inject ambiguous points from the same p_true).
pub fn generator_for(name: &str, seed: u64) -> Generator {
    match name {
        "qmnist" => Generator::new(SynthSpec::vector(D_VEC, 10, 0.8), seed),
        "cifar10" | "cifar10_noise" => Generator::new(SynthSpec::image(D_IMG, 10, 0.95), seed),
        "cifar100" | "cifar100_noise" | "cifar100_relevance" => {
            Generator::new(SynthSpec::image(D_IMG, 100, 1.35), seed)
        }
        "cinic10" | "cinic10_noise" => Generator::new(SynthSpec::image(D_IMG, 10, 0.85), seed),
        "clothing1m" => Generator::new(SynthSpec::image(D_IMG, 14, 1.1), seed),
        "cola" => {
            let mut spec = SynthSpec::vector(D_VEC, 2, 0.8);
            spec.class_weights = Some(vec![0.7, 0.3]);
            Generator::new(spec, seed)
        }
        "sst2" => Generator::new(SynthSpec::vector(D_VEC, 2, 1.0), seed),
        other => panic!("unknown dataset `{other}`"),
    }
}

/// (input_dim, classes) of a named dataset — selects HLO artifacts.
pub fn dims_for(name: &str) -> (usize, usize) {
    match name {
        "qmnist" => (D_VEC, 10),
        "cifar10" | "cifar10_noise" | "cinic10" | "cinic10_noise" => (D_IMG, 10),
        "cifar100" | "cifar100_noise" | "cifar100_relevance" => (D_IMG, 100),
        "clothing1m" => (D_IMG, 14),
        "cola" | "sst2" => (D_VEC, 2),
        other => panic!("unknown dataset `{other}`"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_catalog_entries_build_small() {
        for name in ALL {
            let b = build(name, 1, 0.02);
            assert!(!b.train.is_empty(), "{name} empty train");
            assert!(!b.test.is_empty(), "{name} empty test");
            let (d, c) = dims_for(name);
            assert_eq!(b.train.d, d, "{name} d");
            assert_eq!(b.train.classes, c, "{name} classes");
        }
    }

    #[test]
    fn clothing_is_noisy_and_redundant() {
        let b = build("clothing1m", 2, 0.05);
        assert!(b.train.frac_noisy() > 0.2, "noise {}", b.train.frac_noisy());
        let dups = b.train.meta.iter().filter(|m| m.duplicate).count();
        assert!(dups as f32 / b.train.len() as f32 > 0.4, "dups {dups}");
        // test stays clean
        assert_eq!(b.test.frac_noisy(), 0.0);
    }

    #[test]
    fn noise_variant_adds_ten_percent() {
        let b = build("cifar10_noise", 3, 0.05);
        let f = b.train.frac_noisy();
        assert!((0.06..0.16).contains(&f), "noise frac {f}");
    }

    #[test]
    fn cola_is_imbalanced() {
        let b = build("cola", 4, 0.2);
        let counts = b.train.class_counts();
        assert!(counts[0] as f32 > 1.6 * counts[1] as f32, "{counts:?}");
    }

    #[test]
    fn relevance_dataset_is_skewed() {
        let b = build("cifar100_relevance", 5, 0.1);
        let low = b.train.meta.iter().filter(|m| m.low_relevance).count();
        let frac_low = low as f32 / b.train.len() as f32;
        // ~80 low-relevance classes contribute ~20% of the data
        assert!((0.1..0.35).contains(&frac_low), "low-relevance frac {frac_low}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = build("cifar10", 7, 0.02);
        let b = build("cifar10", 7, 0.02);
        assert_eq!(a.train.xs, b.train.xs);
        assert_eq!(a.train.ys, b.train.ys);
        let c = build("cifar10", 8, 0.02);
        assert_ne!(a.train.ys, c.train.ys);
    }
}
