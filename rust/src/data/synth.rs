//! Synthetic classification generator.
//!
//! Gaussian class prototypes + a fixed random nonlinear warp, with
//! per-class difficulty spread. Design goals (DESIGN.md §2):
//!  - nonlinearity: MLP/CNN clearly beat logistic regression, so the
//!    paper's IL-model-capacity experiments are meaningful;
//!  - controlled Bayes error (prototype margin + class std);
//!  - image-mode prototypes are *smooth* 2-D blobs so conv layers see
//!    local structure;
//!  - the same generator instance is `p_true`: train/holdout/val/test
//!    are iid draws from it, exactly the paper's assumption.

use crate::data::{Dataset, PointMeta};
use crate::util::rng::Pcg32;

/// Generator configuration.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub d: usize,
    pub classes: usize,
    /// Prototype radius; larger = easier (more separated classes).
    pub margin: f32,
    /// Range of per-class noise std (difficulty spread).
    pub class_std: (f32, f32),
    /// Strength of the fixed nonlinear warp (0 = linearly separable).
    pub warp: f32,
    /// Treat features as a sqrt(d) x sqrt(d) image: smooth prototypes.
    pub image_mode: bool,
    /// Per-class sampling weights (None = balanced).
    pub class_weights: Option<Vec<f32>>,
}

impl SynthSpec {
    pub fn vector(d: usize, classes: usize, margin: f32) -> Self {
        SynthSpec {
            d,
            classes,
            margin,
            class_std: (0.9, 1.4),
            warp: 1.0,
            image_mode: false,
            class_weights: None,
        }
    }
    pub fn image(d: usize, classes: usize, margin: f32) -> Self {
        SynthSpec { image_mode: true, ..Self::vector(d, classes, margin) }
    }
}

/// A frozen data-generating distribution `p_true(x, y)`.
pub struct Generator {
    pub spec: SynthSpec,
    /// classes x d prototype matrix.
    protos: Vec<f32>,
    /// per-class noise std.
    stds: Vec<f32>,
    /// d x d warp matrix (low magnitude, applied through tanh).
    warp_w: Vec<f32>,
    /// cumulative class-sampling distribution.
    class_cdf: Vec<f32>,
}

impl Generator {
    pub fn new(spec: SynthSpec, seed: u64) -> Self {
        let mut rng = Pcg32::new(seed, 77);
        let d = spec.d;
        let c = spec.classes;
        let mut protos = vec![0.0f32; c * d];
        for k in 0..c {
            let row = &mut protos[k * d..(k + 1) * d];
            if spec.image_mode {
                smooth_blob(row, &mut rng);
            } else {
                for v in row.iter_mut() {
                    *v = rng.gauss();
                }
            }
            // normalize to radius `margin`
            let norm = row.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-6);
            for v in row.iter_mut() {
                *v *= spec.margin * (d as f32).sqrt() / norm;
            }
        }
        let stds: Vec<f32> =
            (0..c).map(|_| rng.range_f32(spec.class_std.0, spec.class_std.1)).collect();
        let mut warp_w = vec![0.0f32; d * d];
        for v in warp_w.iter_mut() {
            *v = rng.gauss() / (d as f32).sqrt();
        }
        let weights = spec
            .class_weights
            .clone()
            .unwrap_or_else(|| vec![1.0; c]);
        assert_eq!(weights.len(), c);
        let total: f32 = weights.iter().sum();
        let mut acc = 0.0;
        let class_cdf = weights
            .iter()
            .map(|w| {
                acc += w / total;
                acc
            })
            .collect();
        Generator { spec, protos, stds, warp_w, class_cdf }
    }

    pub fn proto(&self, k: usize) -> &[f32] {
        &self.protos[k * self.spec.d..(k + 1) * self.spec.d]
    }

    fn sample_class(&self, rng: &mut Pcg32) -> u32 {
        let u = rng.f32();
        self.class_cdf.iter().position(|&c| u <= c).unwrap_or(self.spec.classes - 1) as u32
    }

    /// Draw the features for class `k` into `out`.
    pub fn sample_x(&self, k: usize, rng: &mut Pcg32, out: &mut [f32]) {
        let d = self.spec.d;
        let proto = self.proto(k);
        let s = self.stds[k];
        // z ~ N(mu_k, s^2 I)
        for (o, &p) in out.iter_mut().zip(proto) {
            *o = p + s * rng.gauss();
        }
        if self.spec.warp > 0.0 {
            // x = z + warp * tanh(W z): fixed nonlinearity shared by all
            // classes; keeps the task non-linearly-separable.
            let z = out.to_vec();
            for i in 0..d {
                let mut acc = 0.0f32;
                let row = &self.warp_w[i * d..(i + 1) * d];
                for (w, zj) in row.iter().zip(&z) {
                    acc += w * zj;
                }
                out[i] = z[i] + self.spec.warp * acc.tanh();
            }
        }
    }

    /// Sample an iid dataset of n points.
    pub fn sample(&self, n: usize, rng: &mut Pcg32) -> Dataset {
        let mut ds = Dataset::empty(self.spec.d, self.spec.classes);
        let mut buf = vec![0.0f32; self.spec.d];
        for _ in 0..n {
            let y = self.sample_class(rng);
            self.sample_x(y as usize, rng, &mut buf);
            ds.push(&buf, y, PointMeta::default());
        }
        ds
    }

    /// Sample an *ambiguous* point: features mix two prototypes, the
    /// label is randomly one of the two (AmbiguousMNIST analogue).
    pub fn sample_ambiguous(&self, rng: &mut Pcg32, buf: &mut [f32]) -> u32 {
        let c = self.spec.classes;
        let a = rng.below(c);
        let b = (a + 1 + rng.below(c - 1)) % c;
        let lam = rng.range_f32(0.35, 0.65);
        let d = self.spec.d;
        let (pa, pb) = (self.proto(a), self.proto(b));
        let s = 0.5 * (self.stds[a] + self.stds[b]);
        for i in 0..d {
            buf[i] = lam * pa[i] + (1.0 - lam) * pb[i] + s * rng.gauss();
        }
        if rng.bernoulli(0.5) { a as u32 } else { b as u32 }
    }

    /// Nearest-prototype pairs (proxy for "most confused classes" used
    /// by the structured-noise injector, Fig. 6).
    pub fn confusable_pairs(&self, k: usize) -> Vec<(u32, u32)> {
        let c = self.spec.classes;
        let mut dists = Vec::new();
        for a in 0..c {
            for b in (a + 1)..c {
                let d2: f32 = self
                    .proto(a)
                    .iter()
                    .zip(self.proto(b))
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum();
                dists.push((d2, a as u32, b as u32));
            }
        }
        dists.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
        dists.into_iter().take(k).map(|(_, a, b)| (a, b)).collect()
    }
}

/// Fill `row` (len s*s) with a sum of random smooth Gaussian bumps.
fn smooth_blob(row: &mut [f32], rng: &mut Pcg32) {
    let d = row.len();
    let s = (d as f32).sqrt() as usize;
    debug_assert_eq!(s * s, d, "image_mode requires square d");
    row.fill(0.0);
    let bumps = 3 + rng.below(3);
    for _ in 0..bumps {
        let cx = rng.range_f32(2.0, s as f32 - 2.0);
        let cy = rng.range_f32(2.0, s as f32 - 2.0);
        let sig = rng.range_f32(1.2, 3.0);
        let amp = rng.range_f32(-1.0, 1.0);
        for yy in 0..s {
            for xx in 0..s {
                let dx = xx as f32 - cx;
                let dy = yy as f32 - cy;
                row[yy * s + xx] += amp * (-(dx * dx + dy * dy) / (2.0 * sig * sig)).exp();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn sample_shapes_and_labels() {
        let g = Generator::new(SynthSpec::vector(16, 5, 2.0), 1);
        let mut rng = Pcg32::new(2, 0);
        let ds = g.sample(500, &mut rng);
        assert_eq!(ds.len(), 500);
        assert_eq!(ds.d, 16);
        assert!(ds.ys.iter().all(|&y| y < 5));
        assert!(ds.xs.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn balanced_classes_roughly_uniform() {
        let g = Generator::new(SynthSpec::vector(8, 4, 2.0), 3);
        let mut rng = Pcg32::new(4, 0);
        let ds = g.sample(4000, &mut rng);
        for count in ds.class_counts() {
            assert!((800..1200).contains(&count), "count {count}");
        }
    }

    #[test]
    fn class_weights_respected() {
        let mut spec = SynthSpec::vector(8, 2, 2.0);
        spec.class_weights = Some(vec![9.0, 1.0]);
        let g = Generator::new(spec, 5);
        let mut rng = Pcg32::new(6, 0);
        let ds = g.sample(5000, &mut rng);
        let counts = ds.class_counts();
        assert!(counts[0] > 4000, "{counts:?}");
        assert!(counts[1] < 1000, "{counts:?}");
    }

    #[test]
    fn margin_orders_separability() {
        // nearest-prototype accuracy should rise with margin
        let acc = |margin: f32| {
            let g = Generator::new(SynthSpec::vector(16, 4, margin), 7);
            let mut rng = Pcg32::new(8, 0);
            let ds = g.sample(1000, &mut rng);
            let mut correct = 0;
            for i in 0..ds.len() {
                let x = ds.x(i);
                let mut best = (f32::INFINITY, 0u32);
                for k in 0..4 {
                    let d2: f32 =
                        g.proto(k).iter().zip(x).map(|(p, v)| (p - v) * (p - v)).sum();
                    if d2 < best.0 {
                        best = (d2, k as u32);
                    }
                }
                if best.1 == ds.ys[i] {
                    correct += 1;
                }
            }
            correct as f32 / ds.len() as f32
        };
        let (lo, hi) = (acc(0.5), acc(3.0));
        assert!(hi > lo + 0.1, "margin 3.0 acc {hi} vs 0.5 acc {lo}");
        assert!(hi > 0.9);
    }

    #[test]
    fn image_mode_prototypes_are_smooth() {
        let g = Generator::new(SynthSpec::image(256, 3, 2.0), 11);
        // total variation of a smooth blob is much lower than white noise
        for k in 0..3 {
            let p = g.proto(k);
            let s = 16;
            let mut tv = 0.0f32;
            let mut energy = 0.0f32;
            for y in 0..s {
                for x in 0..s - 1 {
                    tv += (p[y * s + x + 1] - p[y * s + x]).abs();
                    energy += p[y * s + x].abs();
                }
            }
            assert!(tv < energy, "prototype {k} not smooth: tv={tv} energy={energy}");
        }
    }

    #[test]
    fn ambiguous_labels_from_pair_prop() {
        prop::check("ambiguous-pair", 30, |rng| {
            let g = Generator::new(SynthSpec::vector(8, 6, 2.0), 13);
            let mut buf = vec![0.0; 8];
            let y = g.sample_ambiguous(rng, &mut buf);
            if y >= 6 {
                return Err(format!("label {y} out of range"));
            }
            if buf.iter().any(|v| !v.is_finite()) {
                return Err("non-finite features".into());
            }
            Ok(())
        });
    }

    #[test]
    fn confusable_pairs_sorted_and_valid() {
        let g = Generator::new(SynthSpec::vector(8, 6, 2.0), 17);
        let pairs = g.confusable_pairs(4);
        assert_eq!(pairs.len(), 4);
        for (a, b) in pairs {
            assert!(a < 6 && b < 6 && a != b);
        }
    }

    #[test]
    fn generator_is_deterministic() {
        let g1 = Generator::new(SynthSpec::vector(8, 3, 2.0), 42);
        let g2 = Generator::new(SynthSpec::vector(8, 3, 2.0), 42);
        assert_eq!(g1.proto(0), g2.proto(0));
        let mut r1 = Pcg32::new(1, 0);
        let mut r2 = Pcg32::new(1, 0);
        assert_eq!(g1.sample(10, &mut r1).xs, g2.sample(10, &mut r2).xs);
    }
}
