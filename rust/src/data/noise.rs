//! Label-noise and redundancy injectors (paper §4.3, Fig. 6, App. C).
//!
//! All injectors mark `PointMeta` ground truth so trackers can measure
//! exactly what fraction of *selected* points were corrupted — the
//! measurement behind Fig. 3 (left) and Fig. 7 (left).

use crate::data::synth::Generator;
use crate::data::{Dataset, PointMeta};
use crate::util::rng::Pcg32;

/// Uniform label noise: each point's label is resampled uniformly from
/// the *other* classes with probability `frac` (paper's "10% uniform
/// label noise").
pub fn uniform_label_noise(ds: &mut Dataset, frac: f32, rng: &mut Pcg32) {
    let c = ds.classes as u32;
    for i in 0..ds.len() {
        if rng.bernoulli(frac) {
            let old = ds.ys[i];
            let mut newy = rng.below((c - 1) as usize) as u32;
            if newy >= old {
                newy += 1;
            }
            ds.ys[i] = newy;
            ds.meta[i].noisy = true;
        }
    }
}

/// Structured confusion noise (Rolnick et al. '17 / Fig. 6 middle):
/// flip labels *within* the most-confusable class pairs with
/// probability `p` (both directions).
pub fn structured_confusion_noise(
    ds: &mut Dataset,
    pairs: &[(u32, u32)],
    p: f32,
    rng: &mut Pcg32,
) {
    for i in 0..ds.len() {
        let y = ds.ys[i];
        for &(a, b) in pairs {
            if (y == a || y == b) && rng.bernoulli(p) {
                ds.ys[i] = if y == a { b } else { a };
                ds.meta[i].noisy = true;
                break;
            }
        }
    }
}

/// Append `n` ambiguous prototype-mixture points (AmbiguousMNIST
/// analogue, Fig. 6 right).
pub fn append_ambiguous(ds: &mut Dataset, gen: &Generator, n: usize, rng: &mut Pcg32) {
    let mut buf = vec![0.0f32; ds.d];
    for _ in 0..n {
        let y = gen.sample_ambiguous(rng, &mut buf);
        ds.push(&buf, y, PointMeta { ambiguous: true, noisy: true, ..Default::default() });
    }
}

/// Duplicate points until the dataset reaches `target_len`, adding
/// small feature jitter — the web-scrape redundancy model. Duplicates
/// keep their source's label (and noisy flag) and set `duplicate`.
pub fn duplicate_to(ds: &mut Dataset, target_len: usize, jitter: f32, rng: &mut Pcg32) {
    let base = ds.len();
    assert!(base > 0);
    let mut buf = vec![0.0f32; ds.d];
    while ds.len() < target_len {
        let src = rng.below(base);
        buf.clear();
        buf.extend_from_slice(ds.x(src));
        for v in buf.iter_mut() {
            *v += jitter * rng.gauss();
        }
        let meta = PointMeta { duplicate: true, ..ds.meta[src] };
        let y = ds.ys[src];
        ds.push(&buf, y, meta);
    }
}

/// Down-sample classes to mimic the CIFAR100-Relevance construction:
/// keep every point of `high` classes, keep `keep_frac` of the rest and
/// mark survivors `low_relevance`.
pub fn relevance_filter(ds: &Dataset, high: &[u32], keep_frac: f32, rng: &mut Pcg32) -> Dataset {
    let mut out = Dataset::empty(ds.d, ds.classes);
    for i in 0..ds.len() {
        let y = ds.ys[i];
        if high.contains(&y) {
            out.push(ds.x(i), y, ds.meta[i]);
        } else if rng.bernoulli(keep_frac) {
            let meta = PointMeta { low_relevance: true, ..ds.meta[i] };
            out.push(ds.x(i), y, meta);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::util::prop;

    fn mkds(n: usize, c: usize) -> Dataset {
        let g = Generator::new(SynthSpec::vector(8, c, 2.0), 3);
        let mut rng = Pcg32::new(1, 0);
        g.sample(n, &mut rng)
    }

    #[test]
    fn uniform_noise_rate_and_flags() {
        let mut ds = mkds(5000, 10);
        let orig = ds.ys.clone();
        let mut rng = Pcg32::new(2, 0);
        uniform_label_noise(&mut ds, 0.1, &mut rng);
        let flipped = ds.ys.iter().zip(&orig).filter(|(a, b)| a != b).count();
        assert!((400..600).contains(&flipped), "flipped {flipped}");
        // meta.noisy marks exactly the flipped points
        for i in 0..ds.len() {
            assert_eq!(ds.meta[i].noisy, ds.ys[i] != orig[i]);
        }
    }

    #[test]
    fn uniform_noise_never_keeps_label_prop() {
        prop::check("noise-flips", 20, |rng| {
            let mut ds = mkds(200, 5);
            let orig = ds.ys.clone();
            uniform_label_noise(&mut ds, 1.0, rng);
            for i in 0..ds.len() {
                if ds.ys[i] == orig[i] {
                    return Err(format!("label {i} unchanged at frac=1.0"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn structured_noise_stays_in_pairs() {
        let mut ds = mkds(3000, 10);
        let orig = ds.ys.clone();
        let mut rng = Pcg32::new(5, 0);
        let pairs = vec![(0u32, 1u32), (2, 3)];
        structured_confusion_noise(&mut ds, &pairs, 0.5, &mut rng);
        let mut flips = 0;
        for i in 0..ds.len() {
            if ds.ys[i] != orig[i] {
                flips += 1;
                let pair_ok = pairs
                    .iter()
                    .any(|&(a, b)| (orig[i] == a && ds.ys[i] == b) || (orig[i] == b && ds.ys[i] == a));
                assert!(pair_ok, "flip {} -> {} not in pairs", orig[i], ds.ys[i]);
            }
        }
        assert!(flips > 100, "flips {flips}");
    }

    #[test]
    fn duplicates_marked_and_jittered() {
        let mut ds = mkds(100, 5);
        let mut rng = Pcg32::new(7, 0);
        duplicate_to(&mut ds, 300, 0.05, &mut rng);
        assert_eq!(ds.len(), 300);
        let dups = ds.meta.iter().filter(|m| m.duplicate).count();
        assert_eq!(dups, 200);
    }

    #[test]
    fn relevance_filter_keeps_high_classes() {
        let ds = mkds(4000, 10);
        let mut rng = Pcg32::new(9, 0);
        let high = vec![0u32, 1];
        let out = relevance_filter(&ds, &high, 0.06, &mut rng);
        let counts = out.class_counts();
        let in_counts = ds.class_counts();
        assert_eq!(counts[0], in_counts[0]);
        assert_eq!(counts[1], in_counts[1]);
        for k in 2..10 {
            assert!(counts[k] < in_counts[k] / 4, "class {k}: {} vs {}", counts[k], in_counts[k]);
        }
        for i in 0..out.len() {
            assert_eq!(out.meta[i].low_relevance, !high.contains(&out.ys[i]));
        }
    }

    #[test]
    fn ambiguous_points_flagged() {
        let g = Generator::new(SynthSpec::vector(8, 5, 2.0), 3);
        let mut ds = mkds(10, 5);
        let mut rng = Pcg32::new(11, 0);
        append_ambiguous(&mut ds, &g, 20, &mut rng);
        assert_eq!(ds.len(), 30);
        assert_eq!(ds.meta.iter().filter(|m| m.ambiguous).count(), 20);
    }
}
