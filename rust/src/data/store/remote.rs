//! The remote shard plane: train against a store you never fully
//! download.
//!
//! [`RemoteShardSet`] is a [`DataSource`] whose shards live behind an
//! HTTP server (`data.source = http://host:port/dir`). It plans from
//! the binary [`StoreManifest`](super::manifest::StoreManifest)
//! (fetched once at open), pulls each shard with one HTTP/1.1 *ranged
//! read* (`Range: bytes=`) the first time a row in it is gathered or
//! prefetched, verifies the payload XXH64 on arrival (a mismatch is a
//! hard error — wire bytes are never trusted), and parks it in the
//! bounded [`ShardCache`] where LRU eviction follows the sampler's
//! shuffle window. The engine's existing prefetcher thread calls
//! [`DataSource::prefetch`] with the sampler's upcoming window, which
//! here means *fetch the next window's shards off-thread before
//! `gather` needs them* — the same hook that `madvise`s a local mmap
//! store warms the cache for a remote one.
//!
//! The HTTP client is std-only (`TcpStream`; the vendored crate set
//! has no HTTP client): per-request connect/read/write timeouts,
//! `Connection: close` (one connection per shard fetch — shards are
//! hundreds of KB, connection reuse is not the bottleneck), bounded
//! retry with exponential backoff on connect errors, timeouts, and
//! 5xx responses. 4xx responses are fatal (404 is a distinct
//! `NotFound`, used to probe optional IL sidecars).
//!
//! Determinism: the manifest carries the same per-shard rows and
//! payload checksums the local `ShardSet` derives from the files, so
//! `layout()` and `content_fingerprint()` are bit-identical to the
//! local open — the same seed/config trains bitwise-identically over
//! memory, local shards, or remote shards, and a mid-shard checkpoint
//! written against one source resumes against another.
//!
//! The same machinery doubles as the *local eviction mode*: a
//! [`DirTransport`] serves shard bytes from a local split dir through
//! the identical verify-and-cache path, so an mmap-less host (or one
//! whose RAM is smaller than the store) streams a local store under
//! the same `store.cache_bytes` bound instead of holding every
//! heap-fallback shard resident.
//!
//! Failure contract: `gather`/`point_meta` are infallible by trait, so
//! an *unrecoverable* fetch failure (retries exhausted, checksum
//! mismatch, manifest disagreement) panics with a message naming the
//! shard and source. The panic propagates through the engine's scoped
//! producer join ("candidate producer panicked") — a remote store that
//! disappears mid-run ends the run loudly, never silently corrupts it.

use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::cache::{CacheStats, ShardCache, ShardPayload};
use super::format::{self, shard_file_name};
use super::manifest::{ShardEntry, SplitManifest, StoreManifest, MANIFEST_FILE};
use super::DataSource;
use crate::data::loader::ShardLayout;
use crate::data::{Dataset, PointMeta};

/// Fetch policy for one remote store (from the `store.*` config keys).
#[derive(Clone, Copy, Debug)]
pub struct FetchOpts {
    /// Per-request connect/read/write timeout (0 = wait forever).
    pub timeout_ms: u64,
    /// Retries after the first attempt on retryable failures
    /// (connect/timeout/5xx), with 50ms·2^attempt backoff.
    pub retries: u32,
}

impl Default for FetchOpts {
    fn default() -> Self {
        FetchOpts { timeout_ms: 5000, retries: 3 }
    }
}

/// `http://host[:port]/dir` → (host, port, "/dir"). Only plain HTTP —
/// this is a data plane for stores you control, not the open web.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HttpTarget {
    pub host: String,
    pub port: u16,
    /// Normalized base path ("" or "/dir", no trailing slash).
    pub base: String,
}

/// Parse an `http://` source URL. `None` when the string is not an
/// HTTP source (it may still be `shards://` or a memory catalog name).
pub fn parse_http_source(source: &str) -> Option<HttpTarget> {
    let rest = source.strip_prefix("http://")?;
    let (authority, path) = match rest.find('/') {
        Some(i) => (&rest[..i], rest[i..].trim_end_matches('/')),
        None => (rest, ""),
    };
    let (host, port) = match authority.rsplit_once(':') {
        Some((h, p)) => (h, p.parse::<u16>().ok()?),
        None => (authority, 80),
    };
    if host.is_empty() {
        return None;
    }
    Some(HttpTarget { host: host.to_string(), port, base: path.to_string() })
}

/// Why a fetch failed — drives the retry/probe logic.
#[derive(Debug)]
pub enum FetchError {
    /// 404 — the resource does not exist (used to probe sidecars).
    NotFound(String),
    /// Non-retryable failure (4xx other than 404, malformed response).
    Fatal(String),
    /// Retries exhausted on retryable failures (connect/timeout/5xx).
    Exhausted(String),
}

impl std::fmt::Display for FetchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FetchError::NotFound(m) => write!(f, "not found: {m}"),
            FetchError::Fatal(m) => write!(f, "{m}"),
            FetchError::Exhausted(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for FetchError {}

/// A minimal std-only HTTP/1.1 GET client bound to one host:port.
/// Cheap to clone (no pooled connections — every request is
/// `Connection: close`).
#[derive(Clone, Debug)]
pub struct HttpClient {
    target: HttpTarget,
    opts: FetchOpts,
}

impl HttpClient {
    pub fn new(target: HttpTarget, opts: FetchOpts) -> HttpClient {
        HttpClient { target, opts }
    }

    /// Absolute URL of a path under the target base (for errors/docs).
    pub fn url(&self, path: &str) -> String {
        format!("http://{}:{}{}{path}", self.target.host, self.target.port, self.target.base)
    }

    /// GET `base + path`, optionally with `Range: bytes=start-end`
    /// (inclusive). Retries per [`FetchOpts`]; returns the body.
    pub fn fetch(&self, path: &str, range: Option<(u64, u64)>) -> Result<Vec<u8>, FetchError> {
        let mut last = String::new();
        for attempt in 0..=self.opts.retries {
            if attempt > 0 {
                std::thread::sleep(Duration::from_millis(50u64 << (attempt - 1).min(6)));
            }
            match self.attempt(path, range) {
                Ok((status, body)) => match status {
                    200 | 206 => return Ok(body),
                    404 => return Err(FetchError::NotFound(self.url(path))),
                    s if s >= 500 => {
                        last = format!("HTTP {s} from {}", self.url(path));
                    }
                    s => {
                        return Err(FetchError::Fatal(format!(
                            "HTTP {s} from {} (not retryable)",
                            self.url(path)
                        )))
                    }
                },
                Err(e) => {
                    last = format!("{} fetching {}", e, self.url(path));
                }
            }
        }
        Err(FetchError::Exhausted(format!(
            "{last} (after {} attempts)",
            self.opts.retries + 1
        )))
    }

    /// One request/response cycle. Any `io::Error` (connect, timeout,
    /// short read) is retryable; the caller classifies status codes.
    fn attempt(&self, path: &str, range: Option<(u64, u64)>) -> std::io::Result<(u16, Vec<u8>)> {
        let timeout = (self.opts.timeout_ms > 0)
            .then(|| Duration::from_millis(self.opts.timeout_ms));
        let addr = (self.target.host.as_str(), self.target.port)
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| {
                std::io::Error::new(std::io::ErrorKind::NotFound, "host resolved to no address")
            })?;
        let mut stream = match timeout {
            Some(t) => TcpStream::connect_timeout(&addr, t)?,
            None => TcpStream::connect(addr)?,
        };
        stream.set_read_timeout(timeout)?;
        stream.set_write_timeout(timeout)?;
        let range_header = match range {
            Some((a, b)) => format!("Range: bytes={a}-{b}\r\n"),
            None => String::new(),
        };
        let req = format!(
            "GET {}{path} HTTP/1.1\r\nHost: {}\r\n{range_header}Connection: close\r\n\r\n",
            self.target.base, self.target.host
        );
        stream.write_all(req.as_bytes())?;
        read_response(&mut stream)
    }
}

/// Read one HTTP/1.1 response: status code + body (Content-Length
/// exact when present, else read-to-EOF under `Connection: close`).
fn read_response(stream: &mut TcpStream) -> std::io::Result<(u16, Vec<u8>)> {
    let bad = |m: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, m.to_string());
    // Accumulate until the header terminator; 64 KiB of headers is
    // already implausible for a shard server.
    let mut buf = Vec::with_capacity(1024);
    let mut chunk = [0u8; 4096];
    let header_end = loop {
        if let Some(i) = find_subslice(&buf, b"\r\n\r\n") {
            break i + 4;
        }
        if buf.len() > 64 * 1024 {
            return Err(bad("response headers exceed 64 KiB"));
        }
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed before response headers completed",
            ));
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = std::str::from_utf8(&buf[..header_end])
        .map_err(|_| bad("response headers are not UTF-8"))?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| bad(&format!("malformed status line `{status_line}`")))?;
    let content_length: Option<usize> = lines
        .filter_map(|l| l.split_once(':'))
        .find(|(k, _)| k.trim().eq_ignore_ascii_case("content-length"))
        .and_then(|(_, v)| v.trim().parse().ok());
    let mut body = buf[header_end..].to_vec();
    match content_length {
        Some(n) => {
            if body.len() > n {
                return Err(bad("body exceeds Content-Length"));
            }
            let start = body.len();
            body.resize(n, 0);
            stream.read_exact(&mut body[start..])?;
        }
        None => {
            stream.read_to_end(&mut body)?;
        }
    }
    Ok((status, body))
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Where a split's shard bytes come from: an HTTP range server or a
/// local directory (the eviction mode for mmap-less / RAM-bounded
/// hosts). Either way the bytes land in [`ShardPayload::from_bytes`],
/// which verifies the checksum on every arrival.
pub trait ShardTransport: Send + Sync {
    /// Full file image of shard `i`.
    fn fetch_shard(&self, i: usize, entry: &ShardEntry) -> Result<Vec<u8>>;
    /// An auxiliary split file (e.g. an IL sidecar); `Ok(None)` when it
    /// does not exist.
    fn fetch_aux(&self, name: &str) -> Result<Option<Vec<u8>>>;
    /// Human-readable location of shard `i` for error messages.
    fn describe(&self, i: usize) -> String;
    /// `run_summary` source kind for a set over this transport.
    fn kind(&self) -> &'static str;
}

/// Ranged HTTP reads against `base/split/shard-NNNNN.rsd`.
pub struct HttpTransport {
    pub client: HttpClient,
    /// Path under the client base, e.g. `/train`.
    pub split_path: String,
}

impl ShardTransport for HttpTransport {
    fn fetch_shard(&self, i: usize, entry: &ShardEntry) -> Result<Vec<u8>> {
        let path = format!("{}/{}", self.split_path, shard_file_name(i));
        // Every shard is its own file today, so the range is the whole
        // file — but going through `Range:` keeps the server honest
        // and is exactly the request shape a single-blob split needs.
        let body = self
            .client
            .fetch(&path, Some((0, entry.length - 1)))
            .with_context(|| format!("fetching shard {}", self.describe(i)))?;
        if body.len() as u64 != entry.length {
            bail!(
                "{}: server returned {} bytes, manifest says {} (range request ignored or \
                 store changed under us)",
                self.describe(i),
                body.len(),
                entry.length
            );
        }
        Ok(body)
    }

    fn fetch_aux(&self, name: &str) -> Result<Option<Vec<u8>>> {
        match self.client.fetch(&format!("{}/{name}", self.split_path), None) {
            Ok(b) => Ok(Some(b)),
            Err(FetchError::NotFound(_)) => Ok(None),
            Err(e) => Err(e).with_context(|| format!("fetching {name} over HTTP")),
        }
    }

    fn describe(&self, i: usize) -> String {
        self.client.url(&format!("{}/{}", self.split_path, shard_file_name(i)))
    }

    fn kind(&self) -> &'static str {
        "remote"
    }
}

/// Plain file reads from a local split dir — the local eviction mode.
pub struct DirTransport {
    pub dir: PathBuf,
}

impl ShardTransport for DirTransport {
    fn fetch_shard(&self, i: usize, entry: &ShardEntry) -> Result<Vec<u8>> {
        let path = self.dir.join(shard_file_name(i));
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading shard {path:?} (split dir {:?})", self.dir))?;
        if bytes.len() as u64 != entry.length {
            bail!(
                "{path:?} is {} bytes, manifest says {} (store changed after the manifest \
                 was written?)",
                bytes.len(),
                entry.length
            );
        }
        Ok(bytes)
    }

    fn fetch_aux(&self, name: &str) -> Result<Option<Vec<u8>>> {
        let path = self.dir.join(name);
        if !path.exists() {
            return Ok(None);
        }
        Ok(Some(std::fs::read(&path).with_context(|| format!("reading {path:?}"))?))
    }

    fn describe(&self, i: usize) -> String {
        self.dir.join(shard_file_name(i)).display().to_string()
    }

    fn kind(&self) -> &'static str {
        "shards"
    }
}

/// One split served through a [`ShardTransport`] and the bounded
/// [`ShardCache`] — the streaming counterpart of [`ShardSet`].
///
/// [`ShardSet`]: super::ShardSet
pub struct RemoteShardSet {
    transport: Box<dyn ShardTransport>,
    entries: Vec<ShardEntry>,
    d: usize,
    classes: usize,
    rows: usize,
    /// Global row index where each shard starts (ascending).
    starts: Vec<u32>,
    /// Concatenated IL sidecar values, when every shard has one.
    il: Option<Vec<f32>>,
    cache: Arc<ShardCache>,
    /// Σ manifest shard lengths — the store-side size of this split.
    total_bytes: u64,
}

impl RemoteShardSet {
    /// Assemble a split over any transport. Probes IL sidecars: a full
    /// set loads as the precomputed-IL table, a partial set is refused
    /// (interrupted `score-il`), none is fine.
    pub fn open(
        transport: Box<dyn ShardTransport>,
        split: &SplitManifest,
        d: usize,
        classes: usize,
        cache: Arc<ShardCache>,
    ) -> Result<RemoteShardSet> {
        if split.shards.is_empty() {
            bail!("split `{}` has no shards in the manifest", split.name);
        }
        let mut starts = Vec::with_capacity(split.shards.len());
        let mut rows = 0usize;
        for e in &split.shards {
            let start = u32::try_from(rows)
                .map_err(|_| anyhow::anyhow!("split `{}` exceeds u32 row addressing", split.name))?;
            starts.push(start);
            rows += e.rows as usize;
        }
        let total_bytes = split.bytes();
        let mut il: Option<Vec<f32>> = None;
        // One probe decides; after that, a hole in the set is an error.
        let sidecar = |i: usize| {
            format::sidecar_path(Path::new(&shard_file_name(i)))
                .display()
                .to_string()
        };
        if let Some(first) = transport.fetch_aux(&sidecar(0))? {
            let mut table = Vec::with_capacity(rows);
            let mut adopt = |bytes: Vec<u8>, i: usize, want: usize| -> Result<()> {
                let name = sidecar(i);
                let vals = format::decode_sidecar(&bytes, Path::new(&name))?;
                if vals.len() != want {
                    bail!("{name}: carries {} IL values for a {want}-row shard", vals.len());
                }
                table.extend_from_slice(&vals);
                Ok(())
            };
            adopt(first, 0, split.shards[0].rows as usize)?;
            for (i, e) in split.shards.iter().enumerate().skip(1) {
                match transport.fetch_aux(&sidecar(i))? {
                    Some(bytes) => adopt(bytes, i, e.rows as usize)?,
                    None => bail!(
                        "split `{}` has an IL sidecar for shard 0 but not shard {i} — \
                         interrupted `rho score-il`? re-run it to complete the set",
                        split.name
                    ),
                }
            }
            il = Some(table);
        }
        Ok(RemoteShardSet {
            transport,
            entries: split.shards.clone(),
            d,
            classes,
            rows,
            starts,
            il,
            cache,
            total_bytes,
        })
    }

    /// Open a local split dir in eviction mode: stream shards through
    /// the bounded cache instead of mapping/holding them all.
    pub fn over_dir(
        root: &Path,
        manifest: &StoreManifest,
        split: &str,
        cache: Arc<ShardCache>,
    ) -> Result<RemoteShardSet> {
        let sm = manifest
            .split(split)
            .ok_or_else(|| anyhow::anyhow!("store {root:?} has no `{split}` split in its manifest"))?;
        RemoteShardSet::open(
            Box::new(DirTransport { dir: root.join(split) }),
            sm,
            manifest.d as usize,
            manifest.classes as usize,
            cache,
        )
    }

    /// Bytes of the in-memory lookup tables (IL sidecar + shard-start
    /// index), shared by the `nbytes`/`resident_bytes` accounting.
    fn table_bytes(&self) -> u64 {
        // lint:allow(parser): observability accounting over in-memory
        // table lengths, not parse offsets; nowhere near overflow.
        (self.il.as_ref().map(|t| t.len() * 4).unwrap_or(0) + self.starts.len() * 4) as u64
    }

    /// (shard index, row within shard) of a global row index.
    fn locate(&self, row: u32) -> (usize, usize) {
        debug_assert!((row as usize) < self.rows);
        let s = self.starts.partition_point(|&start| start <= row) - 1;
        (s, (row - self.starts[s]) as usize)
    }

    /// Cache lookup or transport fetch+verify+insert. Fetch failures
    /// here are `Result`s; [`DataSource::gather`] converts them to the
    /// documented panic.
    fn shard(&self, s: usize) -> Result<Arc<ShardPayload>> {
        // lint:allow(parser): shard index < entries.len(), already
        // bounded by the u32 `starts` table built at open.
        if let Some(p) = self.cache.get(s as u32) {
            return Ok(p);
        }
        self.fetch_into_cache(s)
    }

    fn fetch_into_cache(&self, s: usize) -> Result<Arc<ShardPayload>> {
        let entry = &self.entries[s];
        let bytes = self.transport.fetch_shard(s, entry)?;
        let what = self.transport.describe(s);
        // from_bytes verifies header + payload XXH64 (the on-arrival
        // check); then the manifest must agree — a served store whose
        // shards differ from its manifest is refused, not trained on.
        let payload = ShardPayload::from_bytes(&bytes, &what)?;
        if payload.rows as u64 != entry.rows || payload.checksum != entry.checksum {
            bail!(
                "{what}: shard carries {} rows / checksum {:#018x} but the manifest says \
                 {} rows / {:#018x} — store and manifest disagree",
                payload.rows,
                payload.checksum,
                entry.rows,
                entry.checksum
            );
        }
        if payload.d != self.d || payload.classes != self.classes {
            bail!(
                "{what}: shard is ({}, {} classes) but the store manifest says ({}, {} classes)",
                payload.d,
                payload.classes,
                self.d,
                self.classes
            );
        }
        // lint:allow(parser): same bound as `shard` — index fits u32.
        Ok(self.cache.insert(s as u32, payload))
    }

    fn shard_or_die(&self, s: usize) -> Arc<ShardPayload> {
        match self.shard(s) {
            Ok(p) => p,
            // gather/point_meta are infallible by trait; an
            // unrecoverable fetch ends the run loudly (the engine's
            // producer join reports the panic).
            Err(e) => panic!(
                "unrecoverable shard fetch for {}: {e:#}",
                self.transport.describe(s)
            ),
        }
    }

    /// Materialize the whole split as a dense [`Dataset`] (eval splits
    /// are small by construction; streamed shard by shard).
    pub fn to_dataset(&self) -> Result<Dataset> {
        let mut ds = Dataset::empty(self.d, self.classes);
        for s in 0..self.entries.len() {
            let p = self.shard(s)?;
            for r in 0..p.rows {
                ds.push(p.x(r), p.y(r), format::unpack_meta(p.meta(r)));
            }
        }
        Ok(ds)
    }

    pub fn n_shards(&self) -> usize {
        self.entries.len()
    }
}

impl DataSource for RemoteShardSet {
    fn len(&self) -> usize {
        self.rows
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn source_kind(&self) -> &'static str {
        self.transport.kind()
    }

    fn nbytes(&self) -> u64 {
        // lint:allow(parser): u64 stats accounting, not a parse offset.
        self.table_bytes() + self.total_bytes
    }

    fn resident_bytes(&self) -> u64 {
        // lint:allow(parser): u64 stats accounting, not a parse offset.
        self.table_bytes() + self.cache.bytes()
    }

    fn cache_stats(&self) -> Option<CacheStats> {
        Some(self.cache.stats())
    }

    fn gather(&self, idx: &[u32]) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(idx.len() * self.d);
        let mut ys = Vec::with_capacity(idx.len());
        // Memoize the last shard: within a window, consecutive rows
        // cluster by shard, so most lookups skip the cache lock.
        let mut held: Option<(usize, Arc<ShardPayload>)> = None;
        for &i in idx {
            let (s, r) = self.locate(i);
            if held.as_ref().map(|(hs, _)| *hs) != Some(s) {
                held = Some((s, self.shard_or_die(s)));
            }
            let (_, p) = held.as_ref().expect("set above");
            xs.extend_from_slice(p.x(r));
            // lint:allow(parser): label < classes <= u32 header field,
            // validated at decode; i32 is the XLA-facing label dtype.
            ys.push(p.y(r) as i32);
        }
        (xs, ys)
    }

    fn point_meta(&self, i: u32) -> PointMeta {
        let (s, r) = self.locate(i);
        format::unpack_meta(self.shard_or_die(s).meta(r))
    }

    fn layout(&self) -> Option<ShardLayout> {
        // lint:allow(parser): per-shard rows fit u32 — the open-time
        // `starts` construction would have refused the split otherwise.
        Some(ShardLayout::from_blocks(self.entries.iter().map(|e| e.rows as u32).collect()))
    }

    fn wants_prefetch(&self) -> bool {
        true
    }

    /// The windowed-eviction hook: mark the upcoming window's shards
    /// hot (so LRU pressure lands on shards the shuffle already left),
    /// then fetch any that are missing — off-thread, on the engine's
    /// prefetcher, ahead of the gather that needs them. Best-effort: a
    /// failed prefetch is dropped; the gather path retries and owns
    /// the hard error.
    fn prefetch(&self, upcoming: &[u32]) {
        let mut wanted = vec![false; self.entries.len()];
        for &i in upcoming {
            wanted[self.locate(i).0] = true;
        }
        // lint:allow(parser): shard count fits u32 (bounded by the
        // `starts` table built at open).
        let keys: Vec<u32> = (0..wanted.len() as u32).filter(|&s| wanted[s as usize]).collect();
        self.cache.touch(&keys);
        for &s in &keys {
            if !self.cache.contains(s) {
                let _ = self.fetch_into_cache(s as usize);
            }
        }
    }

    fn il_table(&self) -> Option<&[f32]> {
        self.il.as_deref()
    }

    fn content_fingerprint(&self) -> Option<u64> {
        let mut bytes = Vec::with_capacity(self.entries.len() * 8);
        for e in &self.entries {
            bytes.extend_from_slice(&e.checksum.to_le_bytes());
        }
        Some(crate::util::hash::xxh64(&bytes, 0x1DEA_CAFE))
    }
}

/// A remote store root: manifest + streamed `train/` + on-demand
/// materialized eval splits — the HTTP counterpart of
/// [`ShardStore`](super::ShardStore).
pub struct RemoteStore {
    pub url: String,
    pub name: String,
    pub d: usize,
    pub classes: usize,
    pub shard_rows: usize,
    pub manifest: StoreManifest,
    pub train: RemoteShardSet,
    client: HttpClient,
    cache: Arc<ShardCache>,
}

impl RemoteStore {
    /// Open a store at `http://host:port/dir`: one GET for
    /// `store.rman`, then assemble the streamed train split over a
    /// cache bounded at `cache_bytes` (0 = unbounded).
    pub fn open(url: &str, opts: FetchOpts, cache_bytes: u64) -> Result<RemoteStore> {
        let target = parse_http_source(url)
            .ok_or_else(|| anyhow::anyhow!("`{url}` is not an http://host[:port]/dir source"))?;
        let client = HttpClient::new(target, opts);
        let manifest_url = client.url(&format!("/{MANIFEST_FILE}"));
        let bytes = client
            .fetch(&format!("/{MANIFEST_FILE}"), None)
            .map_err(|e| anyhow::anyhow!("{e}"))
            .with_context(|| {
                format!("fetching the store manifest {manifest_url} (is the store served \
                         and ingested with a binary manifest?)")
            })?;
        let manifest = StoreManifest::decode(&bytes, &manifest_url)?;
        let cache = Arc::new(ShardCache::new(cache_bytes));
        let train_split = manifest
            .split("train")
            .ok_or_else(|| anyhow::anyhow!("{manifest_url}: store has no `train` split"))?;
        let train = RemoteShardSet::open(
            Box::new(HttpTransport { client: client.clone(), split_path: "/train".into() }),
            train_split,
            manifest.d as usize,
            manifest.classes as usize,
            Arc::clone(&cache),
        )?;
        Ok(RemoteStore {
            url: url.trim_end_matches('/').to_string(),
            name: manifest.name.clone(),
            d: manifest.d as usize,
            classes: manifest.classes as usize,
            shard_rows: manifest.shard_rows as usize,
            manifest,
            train,
            client,
            cache,
        })
    }

    pub fn has_split(&self, split: &str) -> bool {
        self.manifest.split(split).is_some()
    }

    /// Fetch + materialize a non-train split as a dense dataset (eval
    /// splits are small; they bypass the bounded train cache).
    pub fn materialize(&self, split: &str) -> Result<Dataset> {
        let sm = self
            .manifest
            .split(split)
            .ok_or_else(|| anyhow::anyhow!("{} has no `{split}` split", self.url))?;
        let set = RemoteShardSet::open(
            Box::new(HttpTransport {
                client: self.client.clone(),
                split_path: format!("/{split}"),
            }),
            sm,
            self.d,
            self.classes,
            Arc::new(ShardCache::new(0)),
        )?;
        set.to_dataset()
    }

    /// The train split's cache counters (for `run_summary` deltas and
    /// the bench record).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    pub fn cache_bytes(&self) -> u64 {
        self.cache.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn http_source_parsing() {
        assert_eq!(
            parse_http_source("http://127.0.0.1:8080/stores/c1m"),
            Some(HttpTarget { host: "127.0.0.1".into(), port: 8080, base: "/stores/c1m".into() })
        );
        assert_eq!(
            parse_http_source("http://data.host/d/"),
            Some(HttpTarget { host: "data.host".into(), port: 80, base: "/d".into() })
        );
        assert_eq!(
            parse_http_source("http://h:9000"),
            Some(HttpTarget { host: "h".into(), port: 9000, base: "".into() })
        );
        assert!(parse_http_source("shards://dir").is_none());
        assert!(parse_http_source("http://").is_none());
        assert!(parse_http_source("http://h:notaport/x").is_none());
        assert!(parse_http_source("qmnist").is_none());
    }

    #[test]
    fn client_url_joins_base_and_path() {
        let c = HttpClient::new(
            HttpTarget { host: "h".into(), port: 81, base: "/dir".into() },
            FetchOpts::default(),
        );
        assert_eq!(c.url("/train/shard-00000.rsd"), "http://h:81/dir/train/shard-00000.rsd");
    }

    #[test]
    fn find_subslice_locates_header_end() {
        assert_eq!(find_subslice(b"ab\r\n\r\ncd", b"\r\n\r\n"), Some(2));
        assert_eq!(find_subslice(b"abcd", b"\r\n\r\n"), None);
    }
}
