//! Zero-copy shard reading: a validated, immutable view over one
//! shard file.
//!
//! On unix the file is `mmap`ed read-only (raw `libc` FFI — the
//! vendored crate set has no `memmap2`) and the typed column slices
//! (`xs: &[f32]`, `ys: &[u32]`, `meta: &[u8]`) are handed out straight
//! over the mapped region: the 64-byte header keeps every column
//! 4-byte aligned from the page-aligned base, so no deserialization or
//! copy happens between the page cache and the gather loop. Elsewhere
//! (or under `RHO_STORE_NO_MMAP=1`, which tests use to exercise both
//! paths) the file is read into an 8-byte-aligned heap buffer instead
//! — same slices, plain reads, no mapping.
//!
//! `open` validates everything up front — magic, version, dims, exact
//! byte length, and the XXH64 payload checksum — so every later access
//! is infallible slicing. A shard that fails any check is refused with
//! a hard error; there is no partial or best-effort mode.

use std::fs::File;
use std::io::Read;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::data::store::format::{unpack_meta, ShardHeader, HEADER_LEN};
use crate::data::PointMeta;
use crate::util::hash::xxh64;

#[cfg(unix)]
mod mm {
    use std::os::raw::{c_int, c_void};

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
        pub fn madvise(addr: *mut c_void, len: usize, advice: c_int) -> c_int;
    }

    pub const PROT_READ: c_int = 0x1;
    pub const MAP_PRIVATE: c_int = 0x02;
    pub const MADV_WILLNEED: c_int = 3;
}

/// The bytes of one shard file: a read-only mapping, or an
/// 8-byte-aligned heap copy when mapping is unavailable.
enum Region {
    #[cfg(unix)]
    Mmap {
        ptr: *mut u8,
        len: usize,
    },
    Heap {
        /// `u64` backing guarantees 8-byte alignment for the typed
        /// column views.
        words: Vec<u64>,
        len: usize,
    },
}

// SAFETY: the region is written exactly once (by the kernel / the open
// read) and only ever read afterwards; moving the owning handle to
// another thread transfers nothing but immutable bytes.
unsafe impl Send for Region {}
// SAFETY: all access after open is `&self` reads of bytes that are
// never mutated, so sharing references across the engine's producer,
// prefetcher, and consumer threads cannot race.
unsafe impl Sync for Region {}

impl Region {
    fn bytes(&self) -> &[u8] {
        match self {
            #[cfg(unix)]
            // SAFETY: `ptr` is a live PROT_READ mapping of exactly `len`
            // bytes, unmapped only in Drop, and never written after open.
            Region::Mmap { ptr, len } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
            // SAFETY: `words` owns div_ceil(len, 8) * 8 >= len bytes of
            // initialized storage and is never mutated after `heap()`.
            Region::Heap { words, len } => unsafe {
                std::slice::from_raw_parts(words.as_ptr() as *const u8, *len)
            },
        }
    }

    fn heap(mut f: File, len: usize) -> Result<Region> {
        let mut words = vec![0u64; len.div_ceil(8)];
        // SAFETY: `words` owns >= len zero-initialized bytes; the
        // exclusive &mut view exists only for this read_exact call.
        let buf = unsafe { std::slice::from_raw_parts_mut(words.as_mut_ptr() as *mut u8, len) };
        f.read_exact(buf)?;
        Ok(Region::Heap { words, len })
    }

    fn open(f: File, len: usize, no_mmap: bool) -> Result<Region> {
        #[cfg(unix)]
        {
            if !no_mmap {
                use std::os::unix::io::AsRawFd;
                // SAFETY: plain read-only PRIVATE mapping of an open fd
                // with a null hint; the -1 sentinel is handled below and
                // a successful mapping is owned until Drop's munmap.
                let ptr = unsafe {
                    mm::mmap(
                        std::ptr::null_mut(),
                        len,
                        mm::PROT_READ,
                        mm::MAP_PRIVATE,
                        f.as_raw_fd(),
                        0,
                    )
                };
                if ptr as isize != -1 {
                    return Ok(Region::Mmap { ptr: ptr as *mut u8, len });
                }
                // fall through to the heap read on any mmap failure
            }
        }
        #[cfg(not(unix))]
        let _ = no_mmap;
        Region::heap(f, len)
    }

    fn is_mmap(&self) -> bool {
        match self {
            #[cfg(unix)]
            Region::Mmap { .. } => true,
            Region::Heap { .. } => false,
        }
    }

    fn advise_willneed(&self) {
        #[cfg(unix)]
        if let Region::Mmap { ptr, len } = self {
            // SAFETY: (ptr, len) is the exact live mapping from open;
            // madvise is a readahead hint with no aliasing effects.
            unsafe {
                mm::madvise(*ptr as *mut std::os::raw::c_void, *len, mm::MADV_WILLNEED);
            }
        }
    }
}

impl Drop for Region {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Region::Mmap { ptr, len } = self {
            // SAFETY: (ptr, len) is the exact mapping returned by mmap
            // at open, unmapped exactly once here; no byte views can
            // outlive self (they borrow &self).
            unsafe {
                mm::munmap(*ptr as *mut std::os::raw::c_void, *len);
            }
        }
    }
}

/// A validated, immutable view over one shard file (see module docs).
pub struct ShardReader {
    pub path: PathBuf,
    pub rows: usize,
    pub d: usize,
    pub classes: usize,
    /// Header's payload XXH64 — also the shard's content identity
    /// (folded into the resume fingerprint, so a re-ingested
    /// same-shape store can't silently resume someone else's run).
    pub checksum: u64,
    region: Region,
}

impl ShardReader {
    /// Open + fully validate one shard file. Refuses wrong magic,
    /// version drift, dimension/length inconsistencies, and payload
    /// checksum mismatches. The `RHO_STORE_NO_MMAP` test/ops hook is
    /// read once, here at the call site — the actual mapping decision
    /// is an explicit parameter ([`Self::open_with`]) so tests
    /// exercise both paths without racing on process-global env state.
    pub fn open(path: &Path) -> Result<ShardReader> {
        Self::open_with(path, std::env::var_os("RHO_STORE_NO_MMAP").is_some())
    }

    /// [`Self::open`] with the mapping decision made explicit:
    /// `no_mmap = true` forces the 8-byte-aligned heap read.
    pub fn open_with(path: &Path, no_mmap: bool) -> Result<ShardReader> {
        let f = File::open(path).with_context(|| format!("opening shard {path:?}"))?;
        let file_len = f.metadata()?.len() as usize;
        if file_len < HEADER_LEN {
            bail!("{path:?}: {file_len} bytes is too short to be a shard");
        }
        let region = Region::open(f, file_len, no_mmap)?;
        let bytes = region.bytes();
        let header = ShardHeader::decode(bytes, path)?;
        match header.file_len() {
            Some(expect) if expect == file_len as u64 => {}
            Some(expect) => bail!(
                "{path:?}: header implies {expect} bytes but the file has {file_len} (truncated or trailing garbage)"
            ),
            None => bail!(
                "{path:?}: header rows/d overflow any possible file length (corrupted header)"
            ),
        }
        // Payload checksum: touches every byte, which for a mapped
        // Clothing-1M-scale store means a full sequential page-in at
        // open. That is the right default (corruption is a hard
        // error, never a training-time surprise), but operators of
        // huge verified-at-rest stores can opt out — structural
        // checks (magic/version/dims/length) always run.
        if std::env::var_os("RHO_STORE_NO_VERIFY").is_none() {
            let payload = &bytes[HEADER_LEN..];
            let got = xxh64(payload, 0);
            if got != header.checksum {
                bail!(
                    "{path:?}: payload checksum mismatch (stored {:#018x}, computed {got:#018x}) — shard is corrupted",
                    header.checksum
                );
            }
        }
        let reader = ShardReader {
            path: path.to_path_buf(),
            rows: header.rows as usize,
            d: header.d as usize,
            classes: header.classes as usize,
            checksum: header.checksum,
            region,
        };
        // SAFETY: every 4-byte pattern is a valid f32; alignment is
        // guaranteed by construction (64-byte header over a page- or
        // u64-aligned base) and asserted below rather than trusted.
        let (prefix, xs, _) = unsafe { reader.xs_bytes().align_to::<f32>() };
        // lint:allow(parser): the comparison IS the overflow/shape check
        // (header rows*d already validated against file_len above).
        if !prefix.is_empty() || xs.len() != reader.rows * reader.d {
            bail!("{path:?}: feature column is not 4-byte aligned (mapping base drifted)");
        }
        Ok(reader)
    }

    fn xs_bytes(&self) -> &[u8] {
        // lint:allow(parser): offsets proven in-bounds at open — the
        // header file_len cross-check rejects any rows/d that overflow.
        &self.region.bytes()[HEADER_LEN..HEADER_LEN + self.rows * self.d * 4]
    }

    fn ys_bytes(&self) -> &[u8] {
        // lint:allow(parser): offsets proven in-bounds at open (header
        // file_len cross-check); see xs_bytes.
        let start = HEADER_LEN + self.rows * self.d * 4;
        // lint:allow(parser): same proof as `start` above.
        &self.region.bytes()[start..start + self.rows * 4]
    }

    /// All features, row-major — a zero-copy view over the region.
    pub fn xs(&self) -> &[f32] {
        // SAFETY: any bit pattern is a valid f32; alignment of the
        // column was asserted once at open (open_with bails otherwise).
        let (_, xs, _) = unsafe { self.xs_bytes().align_to::<f32>() };
        xs
    }

    /// Feature row `i`.
    pub fn x(&self, i: usize) -> &[f32] {
        &self.xs()[i * self.d..(i + 1) * self.d]
    }

    /// All labels — a zero-copy view over the region.
    pub fn ys(&self) -> &[u32] {
        // SAFETY: any bit pattern is a valid u32; the label column
        // starts at HEADER_LEN + rows*d*4, both multiples of 4 over the
        // aligned base asserted at open.
        let (prefix, ys, _) = unsafe { self.ys_bytes().align_to::<u32>() };
        debug_assert!(prefix.is_empty());
        ys
    }

    /// Packed meta bytes, one per row.
    pub fn meta_bytes(&self) -> &[u8] {
        // lint:allow(parser): offsets proven in-bounds at open (header
        // file_len cross-check); see xs_bytes.
        let start = HEADER_LEN + self.rows * self.d * 4 + self.rows * 4;
        // lint:allow(parser): same proof as `start` above.
        &self.region.bytes()[start..start + self.rows]
    }

    pub fn meta(&self, i: usize) -> PointMeta {
        unpack_meta(self.meta_bytes()[i])
    }

    /// On-disk byte length of this shard's file (header + payload) —
    /// the store-side total a source reports as `nbytes`, independent
    /// of whether the bytes are mapped or heap-resident.
    pub fn file_bytes(&self) -> u64 {
        // lint:allow(parser): same sum the open-time file_len check
        // already proved fits the real file, as u64 it cannot overflow.
        (HEADER_LEN + self.rows * self.d * 4 + self.rows * 4 + self.rows) as u64
    }

    /// Heap bytes this reader actually owns (0 when mapped — mapped
    /// pages live in the kernel page cache, not the process heap).
    pub fn resident_bytes(&self) -> u64 {
        match &self.region {
            #[cfg(unix)]
            Region::Mmap { .. } => 0,
            Region::Heap { len, .. } => *len as u64,
        }
    }

    pub fn is_mmap(&self) -> bool {
        self.region.is_mmap()
    }

    /// Hint the kernel that this shard's pages are about to be read
    /// (no-op for heap regions, which are already resident).
    pub fn advise_willneed(&self) {
        self.region.advise_willneed();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::store::format::{encode_shard, pack_meta};

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("rho-reader-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample_image() -> Vec<u8> {
        let xs: Vec<f32> = (0..12).map(|i| i as f32 * 0.5 - 2.0).collect();
        let ys = [1u32, 0, 2, 1];
        let meta = [0u8, pack_meta(PointMeta { duplicate: true, ..Default::default() }), 0, 3];
        encode_shard(3, 3, &xs, &ys, &meta)
    }

    #[test]
    fn open_reads_back_columns_bitwise() {
        let path = tmp("ok.rsd");
        std::fs::write(&path, sample_image()).unwrap();
        let r = ShardReader::open(&path).unwrap();
        assert_eq!((r.rows, r.d, r.classes), (4, 3, 3));
        assert_eq!(r.xs().len(), 12);
        assert_eq!(r.x(2), &[1.0, 1.5, 2.0]);
        assert_eq!(r.ys(), &[1, 0, 2, 1]);
        assert!(r.meta(1).duplicate && !r.meta(1).noisy);
        assert!(r.meta(3).noisy && r.meta(3).low_relevance);
    }

    #[test]
    fn heap_fallback_reads_identically() {
        // No env mutation: the mapping decision is an explicit
        // parameter, so this runs safely under the parallel runner.
        let path = tmp("heap.rsd");
        std::fs::write(&path, sample_image()).unwrap();
        let heap = ShardReader::open_with(&path, true).unwrap();
        let mapped = ShardReader::open_with(&path, false).unwrap();
        assert!(!heap.is_mmap());
        assert!(heap.resident_bytes() > 0);
        assert_eq!(heap.file_bytes(), mapped.file_bytes());
        assert_eq!(heap.file_bytes(), sample_image().len() as u64);
        assert_eq!(heap.xs(), mapped.xs());
        assert_eq!(heap.ys(), mapped.ys());
        assert_eq!(heap.meta_bytes(), mapped.meta_bytes());
        mapped.advise_willneed(); // exercised for coverage; no observable effect
    }

    #[test]
    fn env_hook_still_routes_no_mmap() {
        // The one test that must touch process env: serialized behind
        // the shared env lock (`util::env_lock`).
        let _guard = crate::util::env_lock();
        let path = tmp("envhook.rsd");
        std::fs::write(&path, sample_image()).unwrap();
        std::env::set_var("RHO_STORE_NO_MMAP", "1");
        let heap = ShardReader::open(&path);
        std::env::remove_var("RHO_STORE_NO_MMAP");
        assert!(!heap.unwrap().is_mmap());
    }

    #[test]
    fn refuses_corruption_truncation_and_version_drift() {
        let img = sample_image();
        // corrupted payload byte → checksum refusal
        let path = tmp("corrupt.rsd");
        let mut bad = img.clone();
        bad[HEADER_LEN + 5] ^= 0x10;
        std::fs::write(&path, &bad).unwrap();
        let err = ShardReader::open(&path).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        // truncated file
        let path = tmp("trunc.rsd");
        std::fs::write(&path, &img[..img.len() - 3]).unwrap();
        let err = ShardReader::open(&path).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
        // version drift
        let path = tmp("ver.rsd");
        let mut bad = img.clone();
        bad[8] = 2;
        std::fs::write(&path, &bad).unwrap();
        let err = ShardReader::open(&path).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
        // not a shard at all
        let path = tmp("junk.rsd");
        std::fs::write(&path, b"hello world, definitely not a shard file").unwrap();
        assert!(ShardReader::open(&path).is_err());
    }
}
