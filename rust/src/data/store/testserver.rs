//! In-repo HTTP range server over a shard-store directory — the
//! test/bench harness for the remote data plane. **Not a production
//! server**: it exists so the integration suites, `bench_pipeline`'s
//! remote axis, and CI's remote smoke leg can exercise
//! [`RemoteStore`](super::remote::RemoteStore) hermetically against
//! `127.0.0.1`, including under injected network faults.
//!
//! One accept-loop thread; each accepted connection is handled on its
//! own short-lived thread (requests are `Connection: close`, one
//! exchange per connection). `GET` only; `Range: bytes=a-b` answers
//! `206 Partial Content` with a `Content-Range`, no range answers
//! `200` with the whole file. Paths resolve under the served root with
//! `..` components rejected.
//!
//! Fault knobs ride the PR-7 [`FaultPlan`] grammar — `drop_conn`,
//! `corrupt_payload`, and `http_503` specs match on `step=` = the
//! 0-based ordinal of accepted requests (deterministic: the client
//! fetches serially) and fire once each:
//!
//! ```text
//! http_503@step=2; corrupt_payload@step=5
//! ```
//!
//! `corrupt_payload` flips the response body's last byte — for a shard
//! or sidecar that is payload (never header) territory, so the client
//! sees a clean header and a checksum mismatch, exactly the
//! verify-on-arrival path the chaos suite pins.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::runtime::fault::FaultPlan;

/// A running range server; shuts down (flag + wake + join) on drop.
pub struct TestServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accepted: Arc<AtomicU64>,
    handle: Option<JoinHandle<()>>,
}

impl TestServer {
    /// Serve `root` on an ephemeral 127.0.0.1 port, no faults.
    pub fn serve(root: &Path) -> Result<TestServer> {
        Self::serve_with(root, FaultPlan::empty())
    }

    /// Serve `root` on an ephemeral 127.0.0.1 port under a fault plan.
    pub fn serve_with(root: &Path, plan: FaultPlan) -> Result<TestServer> {
        Self::serve_on(root, 0, plan)
    }

    /// Serve `root` on a fixed port (0 = ephemeral) — the
    /// `rho serve-store` entry point for CI.
    pub fn serve_on(root: &Path, port: u16, plan: FaultPlan) -> Result<TestServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("binding the test store server on 127.0.0.1:{port}"))?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let accepted = Arc::new(AtomicU64::new(0));
        let root = root.to_path_buf();
        let handle = {
            let shutdown = Arc::clone(&shutdown);
            let accepted = Arc::clone(&accepted);
            std::thread::spawn(move || {
                for conn in listener.incoming() {
                    if shutdown.load(Ordering::Relaxed) {
                        return;
                    }
                    let Ok(stream) = conn else { continue };
                    let ordinal = accepted.fetch_add(1, Ordering::Relaxed);
                    let root = root.clone();
                    let plan = plan.clone();
                    std::thread::spawn(move || {
                        // Per-connection errors (client went away,
                        // malformed request) only end that exchange.
                        let _ = handle_conn(stream, &root, &plan, ordinal);
                    });
                }
            })
        };
        Ok(TestServer { addr, shutdown, accepted, handle: Some(handle) })
    }

    pub fn port(&self) -> u16 {
        self.addr.port()
    }

    /// The store source URL clients pass as `data.source`.
    pub fn url(&self) -> String {
        format!("http://127.0.0.1:{}", self.addr.port())
    }

    /// Requests accepted so far (= the next request's fault ordinal).
    pub fn requests(&self) -> u64 {
        self.accepted.load(Ordering::Relaxed)
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // Wake the blocking accept so the loop observes the flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(
    mut stream: TcpStream,
    root: &Path,
    plan: &FaultPlan,
    ordinal: u64,
) -> std::io::Result<()> {
    let (path, range) = match read_request(&mut stream)? {
        Some(r) => r,
        None => return Ok(()), // shutdown wake or EOF before a request
    };
    if plan.net_drop(ordinal) {
        return Ok(()); // close without answering
    }
    if plan.net_503(ordinal) {
        return write_simple(&mut stream, "503 Service Unavailable");
    }
    let Some(file) = resolve(root, &path) else {
        return write_simple(&mut stream, "404 Not Found");
    };
    let Ok(bytes) = std::fs::read(&file) else {
        return write_simple(&mut stream, "404 Not Found");
    };
    let total = bytes.len() as u64;
    let (status, extra, mut body) = match range {
        Some((a, b)) => {
            if a > b || b >= total {
                return write_simple(&mut stream, "416 Range Not Satisfiable");
            }
            (
                "206 Partial Content",
                format!("Content-Range: bytes {a}-{b}/{total}\r\n"),
                bytes[a as usize..=b as usize].to_vec(),
            )
        }
        None => ("200 OK", String::new(), bytes),
    };
    if plan.net_corrupt(ordinal) {
        if let Some(last) = body.last_mut() {
            *last ^= 0x40;
        }
    }
    let head = format!(
        "HTTP/1.1 {status}\r\nContent-Length: {}\r\n{extra}Connection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(&body)
}

/// Read one request head; returns (path, parsed Range) or `None` for
/// an empty connection (the shutdown wake).
fn read_request(stream: &mut TcpStream) -> std::io::Result<Option<(String, Option<(u64, u64)>)>> {
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 1024];
    let head_end = loop {
        if let Some(i) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break i;
        }
        if buf.len() > 16 * 1024 {
            return Ok(None);
        }
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Ok(None);
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let Ok(head) = std::str::from_utf8(&buf[..head_end]) else {
        return Ok(None);
    };
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    if parts.next() != Some("GET") {
        return Ok(None);
    }
    let Some(path) = parts.next() else {
        return Ok(None);
    };
    let range = lines
        .filter_map(|l| l.split_once(':'))
        .find(|(k, _)| k.trim().eq_ignore_ascii_case("range"))
        .and_then(|(_, v)| parse_range(v.trim()));
    Ok(Some((path.to_string(), range)))
}

/// `bytes=a-b` (both bounds required — that is the only shape the
/// client sends).
fn parse_range(v: &str) -> Option<(u64, u64)> {
    let (a, b) = v.strip_prefix("bytes=")?.split_once('-')?;
    Some((a.trim().parse().ok()?, b.trim().parse().ok()?))
}

/// Resolve a request path under the served root; `None` rejects
/// traversal (`..`) and absolute-component tricks.
fn resolve(root: &Path, path: &str) -> Option<PathBuf> {
    let rel = path.strip_prefix('/')?;
    let mut out = root.to_path_buf();
    for comp in rel.split('/') {
        if comp.is_empty() || comp == "." {
            continue;
        }
        if comp == ".." || comp.contains('\\') {
            return None;
        }
        out.push(comp);
    }
    out.is_file().then_some(out)
}

fn write_simple(stream: &mut TcpStream, status: &str) -> std::io::Result<()> {
    stream.write_all(
        format!("HTTP/1.1 {status}\r\nContent-Length: 0\r\nConnection: close\r\n\r\n").as_bytes(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::store::remote::{parse_http_source, FetchError, FetchOpts, HttpClient};

    fn tmp_root(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("rho-testserver-{}-{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(dir.join("train")).unwrap();
        dir
    }

    fn client_for(srv: &TestServer) -> HttpClient {
        HttpClient::new(
            parse_http_source(&srv.url()).unwrap(),
            FetchOpts { timeout_ms: 2000, retries: 2 },
        )
    }

    #[test]
    fn serves_full_and_ranged_reads() {
        let root = tmp_root("basic");
        std::fs::write(root.join("train/blob.bin"), (0u8..=99).collect::<Vec<u8>>()).unwrap();
        let srv = TestServer::serve(&root).unwrap();
        let c = client_for(&srv);
        assert_eq!(c.fetch("/train/blob.bin", None).unwrap(), (0u8..=99).collect::<Vec<u8>>());
        assert_eq!(c.fetch("/train/blob.bin", Some((10, 19))).unwrap(), (10u8..=19).collect::<Vec<u8>>());
        assert!(srv.requests() >= 2);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn missing_paths_and_traversal_are_404() {
        let root = tmp_root("sec");
        std::fs::write(root.join("train/ok.bin"), b"fine").unwrap();
        let srv = TestServer::serve(&root).unwrap();
        let c = client_for(&srv);
        assert!(matches!(c.fetch("/train/nope.bin", None), Err(FetchError::NotFound(_))));
        assert!(matches!(c.fetch("/../etc/passwd", None), Err(FetchError::NotFound(_))));
        assert!(matches!(c.fetch("/train/../../etc/passwd", None), Err(FetchError::NotFound(_))));
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn bad_ranges_are_fatal_not_retried() {
        let root = tmp_root("range");
        std::fs::write(root.join("train/blob.bin"), b"0123456789").unwrap();
        let srv = TestServer::serve(&root).unwrap();
        let c = client_for(&srv);
        let before = srv.requests();
        let err = c.fetch("/train/blob.bin", Some((20, 30))).unwrap_err();
        assert!(matches!(err, FetchError::Fatal(_)), "{err}");
        assert_eq!(srv.requests(), before + 1, "416 must not be retried");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn injected_faults_fire_by_request_ordinal_and_retry_recovers() {
        let root = tmp_root("faults");
        std::fs::write(root.join("train/blob.bin"), b"payload-bytes").unwrap();
        // Request 0: 503. Request 1: dropped connection. Request 2 (the
        // second retry) succeeds.
        let plan = FaultPlan::parse("http_503@step=0; drop_conn@step=1").unwrap();
        let srv = TestServer::serve_with(&root, plan).unwrap();
        let c = client_for(&srv);
        assert_eq!(c.fetch("/train/blob.bin", None).unwrap(), b"payload-bytes");
        assert_eq!(srv.requests(), 3, "503 + drop + success = 3 requests");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn corrupt_payload_flips_a_body_byte() {
        let root = tmp_root("corrupt");
        std::fs::write(root.join("train/blob.bin"), b"abcd").unwrap();
        let plan = FaultPlan::parse("corrupt_payload@step=0").unwrap();
        let srv = TestServer::serve_with(&root, plan).unwrap();
        let c = client_for(&srv);
        let got = c.fetch("/train/blob.bin", None).unwrap();
        assert_eq!(got, b"abc\x24", "last byte flipped by 0x40");
        // the spec fired once; the next read is clean
        assert_eq!(c.fetch("/train/blob.bin", None).unwrap(), b"abcd");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn retries_exhaust_with_a_named_error() {
        let root = tmp_root("exhaust");
        std::fs::write(root.join("train/blob.bin"), b"x").unwrap();
        let plan =
            FaultPlan::parse("http_503@step=0; http_503@step=1; http_503@step=2").unwrap();
        let srv = TestServer::serve_with(&root, plan).unwrap();
        let c = client_for(&srv); // retries=2 → 3 attempts, all 503
        let err = c.fetch("/train/blob.bin", None).unwrap_err();
        assert!(matches!(err, FetchError::Exhausted(_)), "{err}");
        assert!(err.to_string().contains("HTTP 503"), "{err}");
        std::fs::remove_dir_all(&root).ok();
    }
}
