//! ShardStore: the on-disk data plane.
//!
//! Everything above this module consumes training data through the
//! [`DataSource`] trait — the paper-scale abstraction that lets the
//! same engine stream a RAM-sized synthetic [`Dataset`] or a sharded
//! on-disk corpus (`Clothing-1M`-shaped workloads) through one loop:
//!
//! - [`format`] — the versioned, xxhash-checksummed binary shard
//!   layout (+ IL sidecars).
//! - [`writer`] — streaming ingest ([`ShardWriter`], `rho ingest`)
//!   with one-shard bounded memory.
//! - [`reader`] — zero-copy [`ShardReader`]s (mmap with a heap
//!   fallback, columns sliced straight over the mapped region).
//! - [`ShardSet`] — one split directory of shards behind `DataSource`:
//!   random-row gather across mapped shards, layout export for the
//!   two-level [`StreamSampler`](crate::data::loader::StreamSampler),
//!   `madvise`-based window prefetch, and the concatenated IL-sidecar
//!   table (`rho score-il` writes it once; every later run's
//!   `Precomputed` provider reads it back with **zero** IL forward
//!   passes).
//! - [`ShardStore`] — a multi-split store root (`train/` streamed,
//!   `holdout`/`val`/`test` materialized on demand for IL training and
//!   eval) plus `store.json` identity.
//! - [`manifest`] — the versioned binary store manifest (`store.rman`):
//!   one file that names every shard of every split with its byte
//!   `{offset, length, rows, checksum}`, so a remote client learns the
//!   whole store's geometry from **one** ranged read. Layout: magic
//!   `RHOMANIF`, `version:u32`, store identity (`d`, `classes`,
//!   `shard_rows`, name), then per split a name + shard entry table
//!   (offsets contiguous per split), and a trailing `xxh64(body, 0)`
//!   integrity hash. `rho ingest` writes it beside the human-readable
//!   `store.json` twin; [`StoreManifest::from_store_dir`] synthesizes
//!   one from any pre-manifest store on open, so old stores keep
//!   working unchanged.
//! - [`cache`] — [`ShardCache`], the bounded shard-payload LRU behind
//!   every non-mmap read path. Invariant: resident bytes never exceed
//!   `cache_bytes` + the one shard currently in flight; hits, misses,
//!   and evictions are counted into `run_summary` and the bench doc.
//! - [`remote`] — [`RemoteShardSet`]/[`RemoteStore`]: `DataSource`
//!   over HTTP ranged reads (`http://host/dir` sources). Shards are
//!   fetched on demand with per-request timeouts and bounded retries,
//!   xxh64-verified on arrival, and parked in the shared [`ShardCache`]
//!   — so a laptop-sized node trains bitwise-identically against a
//!   store it never fully downloads. The same verify-and-cache path
//!   doubles as the windowed-eviction local mode (`DirTransport`).
//! - [`testserver`] — a threaded in-repo HTTP range server for tests,
//!   with `FaultPlan`-driven fault knobs (`drop_conn`,
//!   `corrupt_payload`, `http_503`).
//!
//! Gather parity contract: a `ShardSet` ingested from a `Dataset`
//! gathers bit-identical `(xs, ys)` buffers for any index list — the
//! store writes the same IEEE bytes it was handed — so a sharded run
//! is bitwise-reproducible against its in-memory twin, and a
//! [`RemoteShardSet`] against both (asserted in
//! `tests/store_integration.rs`).

pub mod cache;
pub mod format;
pub mod manifest;
pub mod reader;
pub mod remote;
pub mod testserver;
pub mod writer;

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::data::loader::ShardLayout;
use crate::data::{Dataset, PointMeta};
use crate::util::json;

pub use cache::{CacheStats, ShardCache};
pub use manifest::{StoreManifest, MANIFEST_FILE};
pub use reader::ShardReader;
pub use remote::{FetchOpts, RemoteShardSet, RemoteStore};
pub use testserver::TestServer;
pub use writer::{ingest_bundle, ingest_csv, write_sidecar, IngestReport, ShardWriter};

/// Store manifest file name at the store root.
pub const STORE_MANIFEST: &str = "store.json";

/// The split names a store may carry, in conventional order.
pub const SPLITS: &[&str] = &["train", "holdout", "val", "test"];

/// `shards://<dir>` → the store root. Any other string is not a shard
/// source (the config's `source=""` means in-memory catalog data).
pub fn parse_source(source: &str) -> Option<&Path> {
    source.strip_prefix("shards://").map(Path::new)
}

/// Where a run's training data lives, classified from `data.source`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SourceSpec {
    /// `""` / a catalog name: dense in-memory [`Dataset`].
    Memory,
    /// `shards://<dir>`: a local [`ShardStore`] root.
    Local(PathBuf),
    /// `http://host[:port]/dir`: a [`RemoteStore`] served over ranged
    /// reads.
    Http(String),
}

/// Classify a `data.source` string into the three planes a run can be
/// constructed over.
pub fn classify_source(source: &str) -> SourceSpec {
    if let Some(dir) = parse_source(source) {
        SourceSpec::Local(dir.to_path_buf())
    } else if source.starts_with("http://") {
        SourceSpec::Http(source.to_string())
    } else {
        SourceSpec::Memory
    }
}

/// Uniform view over training data: dense in-memory [`Dataset`] or
/// on-disk [`ShardSet`]. The engine's producer, tracker, and SVP
/// filter all consume this instead of a concrete container, so *where
/// rows live* is a run-construction choice, not an engine rewrite.
/// `Sync` because the engine's scoped producer/prefetcher threads
/// share the source by reference.
pub trait DataSource: Sync {
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Feature dimension.
    fn dim(&self) -> usize;
    fn classes(&self) -> usize;
    /// `"memory"`, `"shards"`, or `"remote"` — surfaced in the
    /// `run_summary` event.
    fn source_kind(&self) -> &'static str;
    /// Total bytes behind this source — everything a full download
    /// would occupy (shard files on disk or on the remote server,
    /// plus the source's own tables). Contrast
    /// [`resident_bytes`](Self::resident_bytes).
    fn nbytes(&self) -> u64;
    /// Process-resident bytes this source owns *right now*: heap
    /// buffers, cached shard payloads, and tables. Mapped pages are
    /// the kernel's, not ours, so a mapped store reports only its
    /// tables; a windowed remote source reports its cache occupancy.
    /// Defaults to [`nbytes`](Self::nbytes) — dense sources are fully
    /// resident by construction.
    fn resident_bytes(&self) -> u64 {
        self.nbytes()
    }
    /// Shard-cache hit/miss/eviction counters, for sources that fetch
    /// through a [`ShardCache`]. `None` means "no cache in the path".
    fn cache_stats(&self) -> Option<CacheStats> {
        None
    }
    /// Gather rows into contiguous (features, labels) buffers — the
    /// exact semantics of [`Dataset::gather`], bit for bit.
    fn gather(&self, idx: &[u32]) -> (Vec<f32>, Vec<i32>);
    /// Ground-truth provenance flags of one point.
    fn point_meta(&self, i: u32) -> PointMeta;
    /// Physical block layout for the two-level sampler; `None` means
    /// "dense" (the engine derives a layout from config instead).
    fn layout(&self) -> Option<ShardLayout> {
        None
    }
    /// Whether [`prefetch`](Self::prefetch) hints do anything — the
    /// engine only spawns its prefetcher thread (and pays the index
    /// copies) for sources that say yes.
    fn wants_prefetch(&self) -> bool {
        false
    }
    /// Hint that `upcoming` rows are about to be gathered (no-op for
    /// memory sources; `madvise(WILLNEED)` per shard for mapped ones).
    fn prefetch(&self, _upcoming: &[u32]) {}
    /// Precomputed per-row IL table (sidecar-backed), when present.
    fn il_table(&self) -> Option<&[f32]> {
        None
    }
    /// Content identity beyond the block layout, folded into the
    /// session-checkpoint data hash. `None` (dense sources) means only
    /// the layout binds the resume; shard sources return a digest of
    /// their per-shard payload checksums so a re-ingested store with
    /// identical shape but different bytes is refused on resume.
    fn content_fingerprint(&self) -> Option<u64> {
        None
    }
}

impl DataSource for Dataset {
    fn len(&self) -> usize {
        Dataset::len(self)
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn source_kind(&self) -> &'static str {
        "memory"
    }

    fn nbytes(&self) -> u64 {
        Dataset::nbytes(self)
    }

    fn gather(&self, idx: &[u32]) -> (Vec<f32>, Vec<i32>) {
        Dataset::gather(self, idx)
    }

    fn point_meta(&self, i: u32) -> PointMeta {
        self.meta[i as usize]
    }
}

/// Materialize selected rows of any source into a dense [`Dataset`]
/// (the SVP core-set filter's output shape).
pub fn materialize_subset(src: &dyn DataSource, idx: &[u32]) -> Dataset {
    let d = src.dim();
    let mut out = Dataset::empty(d, src.classes());
    let (xs, ys) = src.gather(idx);
    for (k, &i) in idx.iter().enumerate() {
        out.push(&xs[k * d..(k + 1) * d], ys[k] as u32, src.point_meta(i));
    }
    out
}

/// One split directory of validated shards behind [`DataSource`].
pub struct ShardSet {
    pub dir: PathBuf,
    d: usize,
    classes: usize,
    rows: usize,
    shards: Vec<ShardReader>,
    /// Global row index where each shard starts (ascending).
    starts: Vec<u32>,
    /// Concatenated IL sidecar values (global row order), when every
    /// shard carries one.
    il: Option<Vec<f32>>,
    /// Shards already advised `WILLNEED` (prefetch is idempotent).
    advised: Mutex<Vec<bool>>,
}

impl ShardSet {
    /// Open every `shard-*.rsd` of a split directory (name-sorted =
    /// write order), validate uniform dims, and load IL sidecars when
    /// the set carries them. A *partial* sidecar set is refused — it
    /// means an interrupted `score-il`; re-run it.
    pub fn open(dir: &Path) -> Result<ShardSet> {
        let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
            .with_context(|| format!("reading split dir {dir:?}"))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                p.extension().map(|x| x == "rsd").unwrap_or(false)
                    && p.file_name()
                        .map(|n| n.to_string_lossy().starts_with("shard-"))
                        .unwrap_or(false)
            })
            .collect();
        // Numeric order, not lexicographic: zero-padding covers five
        // digits, but a >99,999-shard split ("shard-100000.rsd") must
        // still assemble in ingest order or the global row indexing
        // (and every sidecar offset) silently shifts.
        files.sort_by_key(|p| {
            let num = p
                .file_stem()
                .and_then(|s| s.to_str())
                .and_then(|s| s.strip_prefix("shard-"))
                .and_then(|s| s.parse::<u64>().ok());
            (num.is_none(), num, p.clone())
        });
        if files.is_empty() {
            bail!("{dir:?} contains no shard files (expected shard-*.rsd)");
        }
        let mut shards = Vec::with_capacity(files.len());
        let mut starts = Vec::with_capacity(files.len());
        let mut rows = 0usize;
        for path in &files {
            let r = ShardReader::open(path)?;
            if let Some(first) = shards.first() {
                let f: &ShardReader = first;
                if r.d != f.d || r.classes != f.classes {
                    bail!(
                        "{path:?} is ({}, {} classes) but {dir:?} started as ({}, {} classes)",
                        r.d,
                        r.classes,
                        f.d,
                        f.classes
                    );
                }
            }
            starts.push(rows as u32);
            rows += r.rows;
            shards.push(r);
        }
        let with_sidecar = shards
            .iter()
            .filter(|r| format::sidecar_path(&r.path).exists())
            .count();
        let il = if with_sidecar == shards.len() {
            let mut table = Vec::with_capacity(rows);
            for r in &shards {
                let path = format::sidecar_path(&r.path);
                let bytes = std::fs::read(&path)?;
                let vals = format::decode_sidecar(&bytes, &path)?;
                if vals.len() != r.rows {
                    bail!(
                        "{path:?} carries {} IL values for a {}-row shard",
                        vals.len(),
                        r.rows
                    );
                }
                table.extend_from_slice(&vals);
            }
            Some(table)
        } else if with_sidecar > 0 {
            bail!(
                "{dir:?} has IL sidecars for {with_sidecar} of {} shards — interrupted \
                 `rho score-il`? re-run it to complete the set",
                shards.len()
            );
        } else {
            None
        };
        let n_shards = shards.len();
        let (d, classes) = (shards[0].d, shards[0].classes);
        Ok(ShardSet {
            dir: dir.to_path_buf(),
            d,
            classes,
            rows,
            shards,
            starts,
            il,
            advised: Mutex::new(vec![false; n_shards]),
        })
    }

    /// Bytes of the source-owned side tables (IL values + shard
    /// starts) — counted into both `nbytes` and `resident_bytes`.
    fn table_bytes(&self) -> u64 {
        (self.il.as_ref().map(|t| t.len() * 4).unwrap_or(0) + self.starts.len() * 4) as u64
    }

    /// (shard index, row within shard) of a global row index.
    fn locate(&self, row: u32) -> (usize, usize) {
        debug_assert!((row as usize) < self.rows);
        let s = self.starts.partition_point(|&start| start <= row) - 1;
        (s, (row - self.starts[s]) as usize)
    }

    pub fn shards(&self) -> &[ShardReader] {
        &self.shards
    }

    /// True when every shard has a validated IL sidecar.
    pub fn has_il(&self) -> bool {
        self.il.is_some()
    }

    /// Materialize the whole split as a dense [`Dataset`] (bitwise the
    /// rows that were ingested).
    pub fn to_dataset(&self) -> Dataset {
        let mut ds = Dataset::empty(self.d, self.classes);
        for r in &self.shards {
            ds.xs.extend_from_slice(r.xs());
            ds.ys.extend_from_slice(r.ys());
            ds.meta.extend(r.meta_bytes().iter().map(|&b| format::unpack_meta(b)));
        }
        ds
    }
}

impl DataSource for ShardSet {
    fn len(&self) -> usize {
        self.rows
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn source_kind(&self) -> &'static str {
        "shards"
    }

    fn nbytes(&self) -> u64 {
        self.table_bytes() + self.shards.iter().map(|r| r.file_bytes()).sum::<u64>()
    }

    fn resident_bytes(&self) -> u64 {
        self.table_bytes() + self.shards.iter().map(|r| r.resident_bytes()).sum::<u64>()
    }

    fn gather(&self, idx: &[u32]) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(idx.len() * self.d);
        let mut ys = Vec::with_capacity(idx.len());
        for &i in idx {
            let (s, r) = self.locate(i);
            let shard = &self.shards[s];
            xs.extend_from_slice(shard.x(r));
            ys.push(shard.ys()[r] as i32);
        }
        (xs, ys)
    }

    fn point_meta(&self, i: u32) -> PointMeta {
        let (s, r) = self.locate(i);
        self.shards[s].meta(r)
    }

    fn layout(&self) -> Option<ShardLayout> {
        Some(ShardLayout::from_blocks(self.shards.iter().map(|r| r.rows as u32).collect()))
    }

    fn wants_prefetch(&self) -> bool {
        self.shards.iter().any(|r| r.is_mmap())
    }

    fn prefetch(&self, upcoming: &[u32]) {
        let mut advised = match self.advised.lock() {
            Ok(a) => a,
            Err(_) => return, // a poisoned hint is a dropped hint
        };
        for &i in upcoming {
            let (s, _) = self.locate(i);
            if !advised[s] {
                self.shards[s].advise_willneed();
                advised[s] = true;
            }
        }
        // Once every shard has been advised (≈ one epoch of coverage),
        // re-arm the hints: under memory pressure the kernel evicts
        // pages, and a multi-epoch larger-than-memory run needs the
        // WILLNEED hints again next cycle, not just on first touch.
        if advised.iter().all(|&a| a) {
            advised.fill(false);
        }
    }

    fn il_table(&self) -> Option<&[f32]> {
        self.il.as_deref()
    }

    fn content_fingerprint(&self) -> Option<u64> {
        let mut bytes = Vec::with_capacity(self.shards.len() * 8);
        for r in &self.shards {
            bytes.extend_from_slice(&r.checksum.to_le_bytes());
        }
        Some(crate::util::hash::xxh64(&bytes, 0x1DEA_CAFE))
    }
}

/// A multi-split store root: streamed `train/` plus on-demand
/// materialized eval splits, with `store.json` identity.
pub struct ShardStore {
    pub root: PathBuf,
    pub name: String,
    pub d: usize,
    pub classes: usize,
    pub shard_rows: usize,
    pub train: ShardSet,
}

impl ShardStore {
    pub fn open(root: &Path) -> Result<ShardStore> {
        let manifest_path = root.join(STORE_MANIFEST);
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading store manifest {manifest_path:?} (store dir {root:?} — not an \
                 ingested shard store?)"
            )
        })?;
        let doc = json::parse(&text).map_err(|e| {
            anyhow::anyhow!("decoding store manifest {manifest_path:?} (store dir {root:?}): {e}")
        })?;
        let version = doc.get("version").and_then(|v| v.as_usize()).unwrap_or(0);
        if version != 1 {
            bail!("{manifest_path:?}: store version {version}, this build reads version 1");
        }
        let name = doc.get("name").and_then(|v| v.as_str()).unwrap_or("").to_string();
        let d = doc.get("d").and_then(|v| v.as_usize()).unwrap_or(0);
        let classes = doc.get("classes").and_then(|v| v.as_usize()).unwrap_or(0);
        let shard_rows = doc.get("shard_rows").and_then(|v| v.as_usize()).unwrap_or(0);
        let train = ShardSet::open(&root.join("train"))?;
        if train.dim() != d || DataSource::classes(&train) != classes {
            bail!(
                "{manifest_path:?} declares ({d}, {classes} classes) but train/ shards are ({}, {} classes)",
                train.dim(),
                DataSource::classes(&train)
            );
        }
        Ok(ShardStore { root: root.to_path_buf(), name, d, classes, shard_rows, train })
    }

    pub fn has_split(&self, split: &str) -> bool {
        self.root.join(split).is_dir()
    }

    /// Open a non-train split as a shard set.
    pub fn split(&self, split: &str) -> Result<ShardSet> {
        if !SPLITS.contains(&split) {
            bail!("unknown split `{split}` (known: {SPLITS:?})");
        }
        ShardSet::open(&self.root.join(split))
    }

    /// Materialize a split as a dense dataset (IL training / eval need
    /// dense buffers; these splits are small by construction).
    pub fn materialize(&self, split: &str) -> Result<Dataset> {
        Ok(self.split(split)?.to_dataset())
    }

    /// Where `rho score-il` persists the trained IL model state.
    pub fn il_state_path(&self) -> PathBuf {
        self.root.join("il_state.bin")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("rho-store-{}-{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    fn rand_ds(n: usize, d: usize, classes: usize, rng: &mut Pcg32) -> Dataset {
        let mut ds = Dataset::empty(d, classes);
        let mut x = vec![0.0f32; d];
        for _ in 0..n {
            for v in x.iter_mut() {
                *v = rng.range_f32(-4.0, 4.0);
            }
            let meta = PointMeta {
                noisy: rng.bernoulli(0.25),
                duplicate: rng.bernoulli(0.1),
                ..Default::default()
            };
            ds.push(&x, rng.below(classes) as u32, meta);
        }
        ds
    }

    #[test]
    fn source_uri_parsing() {
        assert_eq!(parse_source("shards://out/c10"), Some(Path::new("out/c10")));
        assert!(parse_source("").is_none());
        assert!(parse_source("cifar10").is_none());
        assert_eq!(classify_source("shards://out/c10"), SourceSpec::Local("out/c10".into()));
        assert_eq!(
            classify_source("http://127.0.0.1:8080/c10"),
            SourceSpec::Http("http://127.0.0.1:8080/c10".into())
        );
        assert_eq!(classify_source(""), SourceSpec::Memory);
        assert_eq!(classify_source("cifar10"), SourceSpec::Memory);
    }

    #[test]
    fn shard_set_gathers_bitwise_like_dataset() {
        let dir = tmp("parity");
        let mut rng = Pcg32::new(11, 1);
        let ds = rand_ds(53, 5, 4, &mut rng);
        let mut w = ShardWriter::create(&dir.join("train"), 5, 4, 8).unwrap();
        w.push_dataset(&ds).unwrap();
        w.finish().unwrap();
        let set = ShardSet::open(&dir.join("train")).unwrap();
        assert_eq!(DataSource::len(&set), 53);
        assert_eq!(set.layout().unwrap().blocks().len(), 7, "6 full + ragged");
        for _ in 0..20 {
            let idx: Vec<u32> = (0..10).map(|_| rng.below(53) as u32).collect();
            let (gx, gy) = DataSource::gather(&set, &idx);
            let (ex, ey) = Dataset::gather(&ds, &idx);
            assert_eq!(gy, ey);
            assert_eq!(gx.len(), ex.len());
            for (a, b) in gx.iter().zip(&ex) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        for i in 0..53u32 {
            assert_eq!(set.point_meta(i), ds.meta[i as usize]);
        }
        // full materialization round-trips too
        let back = set.to_dataset();
        assert_eq!(back.xs, ds.xs);
        assert_eq!(back.ys, ds.ys);
        assert_eq!(back.meta, ds.meta);
        set.prefetch(&[0, 20, 52]); // hint path is exercised, not observable
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn store_round_trips_bundle_and_validates_manifest() {
        let dir = tmp("bundle");
        let mut rng = Pcg32::new(3, 2);
        let bundle = Bundle {
            name: "mini".into(),
            train: rand_ds(40, 4, 3, &mut rng),
            holdout: rand_ds(20, 4, 3, &mut rng),
            val: rand_ds(10, 4, 3, &mut rng),
            test: rand_ds(12, 4, 3, &mut rng),
        };
        let report = ingest_bundle(&bundle, &dir, 16).unwrap();
        assert_eq!(report.splits.len(), 4);
        assert_eq!(report.total_rows(), 82);
        let store = ShardStore::open(&dir).unwrap();
        assert_eq!((store.name.as_str(), store.d, store.classes, store.shard_rows), ("mini", 4, 3, 16));
        assert!(!store.train.has_il());
        let test = store.materialize("test").unwrap();
        assert_eq!(test.xs, bundle.test.xs);
        assert!(store.has_split("val"));
        assert!(store.split("bogus").is_err());
        // manifest/dims drift is refused
        let manifest = dir.join(STORE_MANIFEST);
        let text = std::fs::read_to_string(&manifest).unwrap().replace("\"d\":4", "\"d\":9");
        std::fs::write(&manifest, text).unwrap();
        assert!(ShardStore::open(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sidecars_load_as_il_table_and_partial_sets_are_refused() {
        let dir = tmp("sidecar");
        let mut rng = Pcg32::new(9, 3);
        let ds = rand_ds(20, 3, 2, &mut rng);
        let mut w = ShardWriter::create(&dir.join("train"), 3, 2, 8).unwrap();
        w.push_dataset(&ds).unwrap();
        w.finish().unwrap();
        let set = ShardSet::open(&dir.join("train")).unwrap();
        let table: Vec<f32> = (0..20).map(|i| i as f32 * 0.125).collect();
        let mut off = 0usize;
        let paths: Vec<PathBuf> = set.shards().iter().map(|r| r.path.clone()).collect();
        let rows: Vec<usize> = set.shards().iter().map(|r| r.rows).collect();
        drop(set);
        for (path, n) in paths.iter().zip(&rows) {
            write_sidecar(path, &table[off..off + n]).unwrap();
            off += n;
        }
        let set = ShardSet::open(&dir.join("train")).unwrap();
        assert!(set.has_il());
        assert_eq!(set.il_table().unwrap(), table.as_slice());
        assert!(set.resident_bytes() >= 80, "il table counts as resident");
        assert!(
            set.nbytes() >= set.resident_bytes(),
            "total (files + tables) can never undercount residency for a local set"
        );
        assert!(set.cache_stats().is_none(), "mmap path has no shard cache");
        // partial sidecar set → hard error
        std::fs::remove_file(format::sidecar_path(&paths[1])).unwrap();
        let err = ShardSet::open(&dir.join("train")).unwrap_err().to_string();
        assert!(err.contains("score-il"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    use crate::data::Bundle;

    #[test]
    fn materialize_subset_matches_dataset_subset() {
        let mut rng = Pcg32::new(21, 4);
        let ds = rand_ds(30, 4, 5, &mut rng);
        let idx = [3u32, 0, 29, 7, 7];
        let via_source = materialize_subset(&ds, &idx);
        let direct = ds.subset(&idx);
        assert_eq!(via_source.xs, direct.xs);
        assert_eq!(via_source.ys, direct.ys);
        assert_eq!(via_source.meta, direct.meta);
    }
}
