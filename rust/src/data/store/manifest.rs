//! The versioned binary store manifest (`store.rman`) — the one file
//! a remote reader needs before it can plan ranged reads.
//!
//! `store.json` stays the human-readable twin, but it names only the
//! store-level shape (name/d/classes/shard_rows/splits). Serving a
//! store over HTTP needs the *per-shard* geometry up front — byte
//! offset, byte length, row count, payload checksum for every shard of
//! every split — so a remote client can (1) locate the shard a row
//! lives in, (2) issue one `Range: bytes=` read for exactly that
//! shard, and (3) verify the payload on arrival without trusting the
//! wire. That table is this file, rman-style: a fixed magic/version
//! header, a small store preamble, then one offset/length/rows/checksum
//! record per shard, and a trailing XXH64 of everything before it so a
//! truncated or bit-flipped manifest is a hard open-time error.
//!
//! ```text
//! [ magic "RHOMANIF" | version u32 ]
//! [ d u32 | classes u32 | shard_rows u64 ]
//! [ name_len u32 | name bytes (UTF-8) ]
//! [ n_splits u32 ]
//!   per split:
//!   [ name_len u32 | name bytes | n_shards u32 ]
//!     per shard:
//!     [ offset u64 | length u64 | rows u64 | checksum u64 ]
//! [ xxh64 of all preceding bytes (seed 0) u64 ]
//! ```
//!
//! All integers little-endian. `offset` is the shard's byte offset in
//! the split's *virtual concatenation* (shard files laid end to end in
//! index order) — today every shard is its own file so readers derive
//! per-file ranges from `length` alone, but the offsets mean a future
//! single-blob split needs no format bump. `length` is the full shard
//! file length (64-byte header + payload); `checksum` is the shard
//! header's payload XXH64, so the manifest's checksum column and the
//! shard files' own headers cross-check each other.
//!
//! `rho ingest` writes `store.rman` next to `store.json`. Stores
//! ingested before the manifest existed still open:
//! [`StoreManifest::load`] falls back to [`StoreManifest::from_store_dir`],
//! which reconstructs the table from `store.json` plus each shard's
//! 64-byte header (header reads only — no payload rehash; the payload
//! checksum is still verified at shard-open/arrival time as always).

use anyhow::{bail, Context, Result};
use std::path::Path;

use super::format::{shard_file_name, ShardHeader, HEADER_LEN};
use super::{ShardStore, SPLITS, STORE_MANIFEST};
use crate::util::hash::xxh64;

pub const MANIFEST_MAGIC: &[u8; 8] = b"RHOMANIF";
pub const MANIFEST_VERSION: u32 = 1;
/// File name of the binary manifest at the store root.
pub const MANIFEST_FILE: &str = "store.rman";

/// One shard's geometry: where its bytes live in the split, how many
/// rows it carries, and the payload checksum to verify on arrival.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardEntry {
    /// Byte offset in the split's virtual concatenation.
    pub offset: u64,
    /// Full shard-file byte length (header + payload).
    pub length: u64,
    pub rows: u64,
    /// Payload XXH64 (seed 0) — must equal the shard header's own.
    pub checksum: u64,
}

/// The shard table of one split.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SplitManifest {
    pub name: String,
    pub shards: Vec<ShardEntry>,
}

impl SplitManifest {
    pub fn rows(&self) -> u64 {
        self.shards.iter().map(|s| s.rows).sum()
    }

    pub fn bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.length).sum()
    }
}

/// The decoded manifest: store shape + per-split shard tables.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StoreManifest {
    pub name: String,
    pub d: u32,
    pub classes: u32,
    pub shard_rows: u64,
    pub splits: Vec<SplitManifest>,
}

impl StoreManifest {
    pub fn split(&self, name: &str) -> Option<&SplitManifest> {
        self.splits.iter().find(|s| s.name == name)
    }

    /// Serialize to the on-disk/wire image (including the trailing
    /// integrity hash).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64 + self.splits.iter().map(|s| s.shards.len()).sum::<usize>() * 32);
        out.extend_from_slice(MANIFEST_MAGIC);
        out.extend_from_slice(&MANIFEST_VERSION.to_le_bytes());
        out.extend_from_slice(&self.d.to_le_bytes());
        out.extend_from_slice(&self.classes.to_le_bytes());
        out.extend_from_slice(&self.shard_rows.to_le_bytes());
        push_str(&mut out, &self.name);
        let n_splits = u32::try_from(self.splits.len()).expect("split count fits u32");
        out.extend_from_slice(&n_splits.to_le_bytes());
        for split in &self.splits {
            push_str(&mut out, &split.name);
            let n_shards = u32::try_from(split.shards.len()).expect("shard count fits u32");
            out.extend_from_slice(&n_shards.to_le_bytes());
            for s in &split.shards {
                out.extend_from_slice(&s.offset.to_le_bytes());
                out.extend_from_slice(&s.length.to_le_bytes());
                out.extend_from_slice(&s.rows.to_le_bytes());
                out.extend_from_slice(&s.checksum.to_le_bytes());
            }
        }
        let h = xxh64(&out, 0);
        out.extend_from_slice(&h.to_le_bytes());
        out
    }

    /// Decode and fully validate a manifest image. `what` names the
    /// source (file path or URL) in every error.
    pub fn decode(bytes: &[u8], what: &str) -> Result<StoreManifest> {
        if bytes.len() < 8 + 4 + 8 {
            bail!("{what}: {} bytes is too short for a store manifest", bytes.len());
        }
        if &bytes[0..8] != MANIFEST_MAGIC {
            bail!("{what} is not a RHO store manifest (bad magic {:?})", &bytes[0..8]);
        }
        let version = u32::from_le_bytes(bytes[8..12].try_into().expect("4 bytes"));
        if version != MANIFEST_VERSION {
            bail!(
                "{what}: manifest format version {version}, this build reads version \
                 {MANIFEST_VERSION} — re-ingest the store (format versions are never \
                 silently coerced)"
            );
        }
        let body = &bytes[..bytes.len() - 8];
        let claimed = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().expect("8 bytes"));
        if xxh64(body, 0) != claimed {
            bail!("{what}: manifest checksum mismatch (truncated or corrupted)");
        }
        let mut r = Cursor { buf: body, pos: 12, what };
        let d = r.u32()?;
        let classes = r.u32()?;
        let shard_rows = r.u64()?;
        let name = r.string()?;
        if d == 0 || classes == 0 || shard_rows == 0 {
            bail!("{what}: degenerate manifest (d {d}, classes {classes}, shard_rows {shard_rows})");
        }
        let n_splits = r.u32()? as usize;
        let mut splits = Vec::with_capacity(n_splits);
        for _ in 0..n_splits {
            let split_name = r.string()?;
            let n_shards = r.u32()? as usize;
            let mut shards = Vec::with_capacity(n_shards);
            let mut expect_offset = 0u64;
            for i in 0..n_shards {
                let e = ShardEntry {
                    offset: r.u64()?,
                    length: r.u64()?,
                    rows: r.u64()?,
                    checksum: r.u64()?,
                };
                if e.rows == 0 || e.length <= HEADER_LEN as u64 {
                    bail!(
                        "{what}: split `{split_name}` shard {i} is degenerate \
                         ({} rows, {} bytes)",
                        e.rows,
                        e.length
                    );
                }
                if e.offset != expect_offset {
                    bail!(
                        "{what}: split `{split_name}` shard {i} offset {} does not follow the \
                         previous shard (expected {expect_offset})",
                        e.offset
                    );
                }
                expect_offset += e.length;
                shards.push(e);
            }
            splits.push(SplitManifest { name: split_name, shards });
        }
        if r.pos != body.len() {
            bail!("{what}: {} trailing manifest bytes after the shard table", body.len() - r.pos);
        }
        Ok(StoreManifest { name, d, classes, shard_rows, splits })
    }

    /// Load the manifest of a local store: `store.rman` when present,
    /// else reconstructed from `store.json` + shard headers (stores
    /// ingested before the binary manifest existed).
    pub fn load(root: &Path) -> Result<StoreManifest> {
        let path = root.join(MANIFEST_FILE);
        if path.exists() {
            let bytes = std::fs::read(&path).with_context(|| {
                format!("reading store manifest {path:?} (store dir {root:?})")
            })?;
            return Self::decode(&bytes, &path.display().to_string()).with_context(|| {
                format!("decoding store manifest {path:?} (store dir {root:?})")
            });
        }
        Self::from_store_dir(root)
    }

    /// Compatibility reconstruction for stores that predate
    /// `store.rman`: read `store.json` for the shape, then each shard's
    /// 64-byte header + file length for the table. Header reads only —
    /// payload checksums are taken from the headers, not rehashed.
    pub fn from_store_dir(root: &Path) -> Result<StoreManifest> {
        let store = ShardStore::open(root)
            .with_context(|| format!("reconstructing the manifest of pre-manifest store {root:?}"))?;
        let mut splits = Vec::new();
        for split in SPLITS {
            let dir = root.join(split);
            if !dir.is_dir() {
                continue;
            }
            let mut shards = Vec::new();
            let mut offset = 0u64;
            for i in 0.. {
                let path = dir.join(shard_file_name(i));
                if !path.exists() {
                    break;
                }
                let mut head = [0u8; HEADER_LEN];
                let mut f = std::fs::File::open(&path)
                    .with_context(|| format!("opening shard {path:?} (store dir {root:?})"))?;
                std::io::Read::read_exact(&mut f, &mut head)
                    .with_context(|| format!("reading the header of shard {path:?}"))?;
                let h = ShardHeader::decode(&head, &path)?;
                let length = f
                    .metadata()
                    .with_context(|| format!("statting shard {path:?}"))?
                    .len();
                if Some(length) != h.file_len() {
                    bail!(
                        "shard {path:?} is {length} bytes but its header implies {:?} \
                         (truncated or trailing garbage)",
                        h.file_len()
                    );
                }
                shards.push(ShardEntry { offset, length, rows: h.rows, checksum: h.checksum });
                offset += length;
            }
            if !shards.is_empty() {
                splits.push(SplitManifest { name: split.to_string(), shards });
            }
        }
        if splits.is_empty() {
            bail!("store {root:?} has no shards in any split dir ({SPLITS:?})");
        }
        Ok(StoreManifest {
            name: store.name.clone(),
            d: u32::try_from(store.d).expect("store d fits u32"),
            classes: u32::try_from(store.classes).expect("store classes fits u32"),
            shard_rows: store.shard_rows as u64,
            splits,
        })
    }

    /// Write `store.rman` at the store root (atomic tmp + rename, like
    /// every other store artifact).
    pub fn write(&self, root: &Path) -> Result<()> {
        let path = root.join(MANIFEST_FILE);
        let tmp = path.with_extension("rman.tmp");
        std::fs::write(&tmp, self.encode())
            .with_context(|| format!("writing store manifest {tmp:?} (store dir {root:?})"))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("renaming store manifest into place at {path:?}"))?;
        Ok(())
    }

    /// Resume-identity fingerprint of one split: XXH64 over its shard
    /// checksums in order — bit-identical to what the local
    /// `ShardSet::content_fingerprint` computes from the shard files
    /// themselves, so remote and local opens of the same store agree.
    pub fn content_fingerprint(&self, split: &str) -> Option<u64> {
        let s = self.split(split)?;
        let mut bytes = Vec::with_capacity(s.shards.len() * 8);
        for e in &s.shards {
            bytes.extend_from_slice(&e.checksum.to_le_bytes());
        }
        Some(xxh64(&bytes, 0x1DEA_CAFE))
    }
}

fn push_str(out: &mut Vec<u8>, s: &str) {
    let n = u32::try_from(s.len()).expect("manifest string fits u32");
    out.extend_from_slice(&n.to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

/// Bounds-checked little-endian reader over the manifest body — every
/// short read is a named error, never a panic.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
    what: &'a str,
}

impl Cursor<'_> {
    fn take(&mut self, n: usize) -> Result<&[u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "{}: manifest truncated ({} bytes needed at offset {}, {} available)",
                self.what,
                n,
                self.pos,
                self.buf.len() - self.pos
            );
        }
        let b = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(b)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn string(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        if n > 4096 {
            bail!("{}: manifest string length {n} is implausible (corrupt length field)", self.what);
        }
        let b = self.take(n)?;
        String::from_utf8(b.to_vec())
            .map_err(|_| anyhow::anyhow!("{}: manifest string is not UTF-8", self.what))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> StoreManifest {
        StoreManifest {
            name: "qmnist".into(),
            d: 64,
            classes: 10,
            shard_rows: 640,
            splits: vec![
                SplitManifest {
                    name: "train".into(),
                    shards: vec![
                        ShardEntry { offset: 0, length: 1000, rows: 640, checksum: 0xAB },
                        ShardEntry { offset: 1000, length: 700, rows: 360, checksum: 0xCD },
                    ],
                },
                SplitManifest {
                    name: "test".into(),
                    shards: vec![ShardEntry { offset: 0, length: 500, rows: 200, checksum: 0xEF }],
                },
            ],
        }
    }

    #[test]
    fn manifest_round_trips() {
        let m = sample();
        let img = m.encode();
        let back = StoreManifest::decode(&img, "mem").unwrap();
        assert_eq!(back, m);
        assert_eq!(back.split("train").unwrap().rows(), 1000);
        assert_eq!(back.split("train").unwrap().bytes(), 1700);
        assert!(back.split("holdout").is_none());
    }

    #[test]
    fn manifest_refuses_corruption_truncation_and_drift() {
        let img = sample().encode();
        // bit flip anywhere inside the body trips the trailing hash
        let mut bad = img.clone();
        bad[20] ^= 1;
        let err = StoreManifest::decode(&bad, "m").unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        // truncation
        assert!(StoreManifest::decode(&img[..img.len() - 3], "m").is_err());
        assert!(StoreManifest::decode(&img[..10], "m").is_err());
        // magic / version
        let mut bad = img.clone();
        bad[0] = b'X';
        assert!(StoreManifest::decode(&bad, "m").unwrap_err().to_string().contains("magic"));
        let mut bad = img.clone();
        bad[8] = 9;
        // version check runs before the hash check, so this names the version
        let err = StoreManifest::decode(&bad, "m").unwrap_err().to_string();
        assert!(err.contains("version 9"), "{err}");
    }

    #[test]
    fn manifest_errors_name_the_source() {
        let err = StoreManifest::decode(&[0u8; 4], "http://h/store.rman").unwrap_err().to_string();
        assert!(err.contains("http://h/store.rman"), "{err}");
    }

    #[test]
    fn decode_rejects_non_contiguous_offsets() {
        let mut m = sample();
        m.splits[0].shards[1].offset = 999;
        let err = StoreManifest::decode(&m.encode(), "m").unwrap_err().to_string();
        assert!(err.contains("offset"), "{err}");
    }

    #[test]
    fn content_fingerprint_matches_shardset_formula() {
        let m = sample();
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&0xABu64.to_le_bytes());
        bytes.extend_from_slice(&0xCDu64.to_le_bytes());
        assert_eq!(m.content_fingerprint("train"), Some(xxh64(&bytes, 0x1DEA_CAFE)));
        assert_eq!(m.content_fingerprint("nope"), None);
    }
}
