//! Streaming shard ingest: rows in, checksummed shard files out.
//!
//! [`ShardWriter`] buffers at most one shard (`shard_rows` rows) in
//! memory — that bound is the whole point of the data plane: a corpus
//! of any size streams through `push` with O(shard) residency. Each
//! flush encodes the columnar payload, checksums it, and writes the
//! file in one pass (`shard-NNNNN.rsd`, see
//! [`format`](super::format)).
//!
//! [`ingest_bundle`] writes a full four-split store (one subdirectory
//! per split + `store.json`); [`ingest_csv`] ingests an external
//! `f0,...,fd-1,label` CSV into a train-only store. IL sidecars are
//! written per shard by [`write_sidecar`] (atomic temp + rename, so a
//! crashed `score-il` never leaves a half-written sidecar beside a
//! good shard).

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::data::store::format::{encode_shard, encode_sidecar, pack_meta, shard_file_name};
use crate::data::store::STORE_MANIFEST;
use crate::data::{Bundle, Dataset, PointMeta};
use crate::util::json::{num, obj, s, Value};

/// Outcome of writing one split directory.
#[derive(Clone, Debug)]
pub struct SplitSummary {
    pub split: String,
    pub rows: u64,
    pub shards: usize,
    pub bytes: u64,
}

/// Streams rows into `shard_rows`-sized shard files under one split
/// directory. Buffered rows are bounded by one shard.
pub struct ShardWriter {
    dir: PathBuf,
    d: usize,
    classes: usize,
    shard_rows: usize,
    xs: Vec<f32>,
    ys: Vec<u32>,
    meta: Vec<u8>,
    shards: usize,
    rows: u64,
    bytes: u64,
}

impl ShardWriter {
    pub fn create(dir: &Path, d: usize, classes: usize, shard_rows: usize) -> Result<ShardWriter> {
        if d == 0 || classes == 0 {
            bail!("shard writer needs d > 0 and classes > 0 (got d {d}, classes {classes})");
        }
        if shard_rows == 0 {
            bail!("shard_rows must be positive");
        }
        std::fs::create_dir_all(dir).with_context(|| format!("creating split dir {dir:?}"))?;
        Ok(ShardWriter {
            dir: dir.to_path_buf(),
            d,
            classes,
            shard_rows,
            xs: Vec::with_capacity(shard_rows * d),
            ys: Vec::with_capacity(shard_rows),
            meta: Vec::with_capacity(shard_rows),
            shards: 0,
            rows: 0,
            bytes: 0,
        })
    }

    /// Append one row; flushes a full shard to disk transparently.
    pub fn push(&mut self, x: &[f32], y: u32, meta: PointMeta) -> Result<()> {
        if x.len() != self.d {
            bail!("row has {} features, writer expects {}", x.len(), self.d);
        }
        if y as usize >= self.classes {
            bail!("label {y} out of range for {} classes", self.classes);
        }
        self.xs.extend_from_slice(x);
        self.ys.push(y);
        self.meta.push(pack_meta(meta));
        self.rows += 1;
        if self.ys.len() == self.shard_rows {
            self.flush_shard()?;
        }
        Ok(())
    }

    /// Append every row of a dataset (dims must match).
    pub fn push_dataset(&mut self, ds: &Dataset) -> Result<()> {
        if ds.d != self.d || ds.classes != self.classes {
            bail!(
                "dataset is ({}, {} classes), writer is ({}, {} classes)",
                ds.d,
                ds.classes,
                self.d,
                self.classes
            );
        }
        for i in 0..ds.len() {
            self.push(ds.x(i), ds.ys[i], ds.meta[i])?;
        }
        Ok(())
    }

    fn flush_shard(&mut self) -> Result<()> {
        if self.ys.is_empty() {
            return Ok(());
        }
        let image = encode_shard(self.d, self.classes, &self.xs, &self.ys, &self.meta);
        let path = self.dir.join(shard_file_name(self.shards));
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(&path).with_context(|| format!("creating shard {path:?}"))?,
        );
        f.write_all(&image)?;
        f.flush()?;
        self.bytes += image.len() as u64;
        self.shards += 1;
        self.xs.clear();
        self.ys.clear();
        self.meta.clear();
        Ok(())
    }

    /// Flush the ragged final shard and summarize the split.
    pub fn finish(mut self) -> Result<SplitSummary> {
        self.flush_shard()?;
        if self.rows == 0 {
            bail!("split {:?} received no rows", self.dir);
        }
        let split = self
            .dir
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_default();
        Ok(SplitSummary { split, rows: self.rows, shards: self.shards, bytes: self.bytes })
    }
}

/// Write `<shard>.il` beside its shard, atomically.
pub fn write_sidecar(shard_path: &Path, values: &[f32]) -> Result<()> {
    let path = super::format::sidecar_path(shard_path);
    let tmp = path.with_extension("il.tmp");
    std::fs::write(&tmp, encode_sidecar(values))?;
    std::fs::rename(&tmp, &path).with_context(|| format!("installing sidecar {path:?}"))?;
    Ok(())
}

/// Outcome of one full ingest.
#[derive(Clone, Debug)]
pub struct IngestReport {
    pub root: PathBuf,
    pub name: String,
    pub d: usize,
    pub classes: usize,
    pub shard_rows: usize,
    pub splits: Vec<SplitSummary>,
}

impl IngestReport {
    pub fn total_rows(&self) -> u64 {
        self.splits.iter().map(|s| s.rows).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.splits.iter().map(|s| s.bytes).sum()
    }
}

fn write_store_manifest(report: &IngestReport) -> Result<()> {
    let splits = Value::Array(report.splits.iter().map(|sp| s(&sp.split)).collect());
    let doc = obj(vec![
        ("version", num(1.0)),
        ("name", s(&report.name)),
        ("d", num(report.d as f64)),
        ("classes", num(report.classes as f64)),
        ("shard_rows", num(report.shard_rows as f64)),
        ("splits", splits),
    ]);
    std::fs::write(report.root.join(STORE_MANIFEST), doc.to_json() + "\n")?;
    // The binary twin (`store.rman`): every shard's byte geometry +
    // checksum in one checksummed file, so a remote client learns the
    // whole store from a single ranged read. Synthesized from the
    // just-written directory so the two manifests can never disagree.
    super::manifest::StoreManifest::from_store_dir(&report.root)?.write(&report.root)?;
    Ok(())
}

/// Ingest a full [`Bundle`] into `out/` — one split directory per
/// non-empty split (`train`, `holdout`, `val`, `test`) plus the store
/// manifest.
pub fn ingest_bundle(bundle: &Bundle, out: &Path, shard_rows: usize) -> Result<IngestReport> {
    let (d, classes) = (bundle.train.d, bundle.train.classes);
    if bundle.train.is_empty() {
        bail!("bundle `{}` has an empty train split", bundle.name);
    }
    let mut splits = Vec::new();
    for (name, ds) in [
        ("train", &bundle.train),
        ("holdout", &bundle.holdout),
        ("val", &bundle.val),
        ("test", &bundle.test),
    ] {
        if ds.is_empty() {
            continue;
        }
        let mut w = ShardWriter::create(&out.join(name), d, classes, shard_rows)?;
        w.push_dataset(ds)?;
        splits.push(w.finish()?);
    }
    let report = IngestReport {
        root: out.to_path_buf(),
        name: bundle.name.clone(),
        d,
        classes,
        shard_rows,
        splits,
    };
    write_store_manifest(&report)?;
    Ok(report)
}

/// Ingest an external CSV (`f0,...,fd-1,label` per line, optional
/// header) into a train-only store. Two *streamed* passes over the
/// file — the first discovers `d` and the label range, the second
/// pushes rows into shards — so ingest memory stays O(one shard + one
/// line) even for larger-than-RAM corpora (the data plane's whole
/// point).
pub fn ingest_csv(csv: &Path, out: &Path, shard_rows: usize) -> Result<IngestReport> {
    use std::io::BufRead;
    let open = || -> Result<std::io::BufReader<std::fs::File>> {
        Ok(std::io::BufReader::new(
            std::fs::File::open(csv).with_context(|| format!("reading {csv:?}"))?,
        ))
    };
    // pass 1: dims + label range (streamed)
    let mut d = 0usize;
    let mut max_label = 0u32;
    let mut data_lines = 0usize;
    let mut first_line = true;
    for (i, line) in open()?.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        if fields.len() < 2 {
            bail!("{csv:?}:{}: need at least one feature and a label", i + 1);
        }
        // header = the first NON-BLANK line when it doesn't parse as
        // data (a leading blank line or BOM must not demote it)
        let is_header = first_line && fields[0].parse::<f32>().is_err();
        first_line = false;
        if is_header {
            continue;
        }
        if d == 0 {
            d = fields.len() - 1;
        } else if fields.len() - 1 != d {
            bail!("{csv:?}:{}: {} features, earlier rows had {d}", i + 1, fields.len() - 1);
        }
        let y: u32 = fields[d]
            .parse()
            .map_err(|e| anyhow::anyhow!("{csv:?}:{}: bad label `{}`: {e}", i + 1, fields[d]))?;
        max_label = max_label.max(y);
        data_lines += 1;
    }
    if data_lines == 0 {
        bail!("{csv:?} has no data rows");
    }
    let classes = max_label as usize + 1;
    // pass 2: stream rows into shards
    let mut w = ShardWriter::create(&out.join("train"), d, classes, shard_rows)?;
    let mut x = vec![0.0f32; d];
    let mut first_line = true;
    for (i, line) in open()?.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        let is_header = first_line && fields[0].parse::<f32>().is_err();
        first_line = false;
        if is_header {
            continue;
        }
        for (j, f) in fields[..d].iter().enumerate() {
            x[j] = f
                .parse()
                .map_err(|e| anyhow::anyhow!("{csv:?}:{}: bad feature `{f}`: {e}", i + 1))?;
        }
        let y: u32 = fields[d].parse().expect("validated in first pass");
        w.push(&x, y, PointMeta::default())?;
    }
    let name = csv.file_stem().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default();
    let report = IngestReport {
        root: out.to_path_buf(),
        name,
        d,
        classes,
        shard_rows,
        splits: vec![w.finish()?],
    };
    write_store_manifest(&report)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::store::reader::ShardReader;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("rho-writer-{}-{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    fn tiny_ds(n: usize, d: usize, classes: usize) -> Dataset {
        let mut ds = Dataset::empty(d, classes);
        for i in 0..n {
            let x: Vec<f32> = (0..d).map(|j| (i * d + j) as f32 * 0.25).collect();
            let meta = PointMeta { noisy: i % 3 == 0, ..Default::default() };
            ds.push(&x, (i % classes) as u32, meta);
        }
        ds
    }

    #[test]
    fn writes_full_and_ragged_shards() {
        let dir = tmp("ragged");
        let ds = tiny_ds(10, 3, 4);
        let mut w = ShardWriter::create(&dir.join("train"), 3, 4, 4).unwrap();
        w.push_dataset(&ds).unwrap();
        let sum = w.finish().unwrap();
        assert_eq!((sum.rows, sum.shards), (10, 3), "4+4+2 rows");
        let r0 = ShardReader::open(&dir.join("train").join(shard_file_name(0))).unwrap();
        let r2 = ShardReader::open(&dir.join("train").join(shard_file_name(2))).unwrap();
        assert_eq!((r0.rows, r2.rows), (4, 2));
        assert_eq!(r2.x(1), ds.x(9), "ragged tail keeps row bytes");
        assert!(r0.meta(0).noisy);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn writer_rejects_bad_rows() {
        let dir = tmp("reject");
        let mut w = ShardWriter::create(&dir.join("train"), 2, 3, 8).unwrap();
        assert!(w.push(&[1.0], 0, PointMeta::default()).is_err(), "short row");
        assert!(w.push(&[1.0, 2.0], 3, PointMeta::default()).is_err(), "label overflow");
        assert!(ShardWriter::create(&dir.join("x"), 2, 3, 0).is_err(), "zero shard_rows");
        let empty = ShardWriter::create(&dir.join("y"), 2, 3, 8).unwrap();
        assert!(empty.finish().is_err(), "empty split refused");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_ingest_round_trips() {
        let dir = tmp("csv");
        std::fs::create_dir_all(&dir).unwrap();
        let csv = dir.join("mini.csv");
        // leading blank line: the header is the first NON-blank line
        std::fs::write(&csv, "\na,b,label\n0.5,1.5,0\n-1.0,2.0,2\n3.25,4.5,1\n").unwrap();
        let report = ingest_csv(&csv, &dir.join("store"), 2).unwrap();
        assert_eq!((report.d, report.classes), (2, 3));
        assert_eq!(report.total_rows(), 3);
        assert_eq!(report.splits[0].shards, 2);
        let r = ShardReader::open(&dir.join("store/train").join(shard_file_name(0))).unwrap();
        assert_eq!(r.xs(), &[0.5, 1.5, -1.0, 2.0]);
        assert_eq!(r.ys(), &[0, 2]);
        // ingest writes the binary manifest twin beside store.json
        let m = crate::data::store::StoreManifest::load(&dir.join("store")).unwrap();
        assert_eq!((m.d, m.classes), (2, 3));
        assert_eq!(m.split("train").unwrap().shards.len(), 2);
        assert!(dir.join("store").join(crate::data::store::MANIFEST_FILE).exists());
        // malformed rows are refused
        std::fs::write(&csv, "1.0,2.0,0\n1.0,0\n").unwrap();
        assert!(ingest_csv(&csv, &dir.join("bad"), 2).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
