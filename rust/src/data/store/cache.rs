//! Bounded shard cache: the residency half of the streaming contract.
//!
//! The two-level `StreamSampler` bounds the *working set* to
//! `ceil(window/shard_rows)+1` shards; this cache bounds the *resident
//! bytes* to `store.cache_bytes`, evicting least-recently-used shards
//! as the shuffle window walks the store. It backs both halves of the
//! streaming story:
//!
//! - [`RemoteShardSet`](super::remote::RemoteShardSet) inserts fetched
//!   shards here (a cold `gather` is fetch-and-insert) so a node
//!   trains against a store it never fully downloads, and
//! - the heap-fallback local reader routes through the same cache in
//!   its eviction mode, so an mmap-less or disk-smaller-than-dataset
//!   host streams an arbitrarily large local store too.
//!
//! Invariant: after any `insert`, resident bytes ≤ `cache_bytes` +
//! the just-inserted shard — i.e. the cache only ever overshoots by
//! the one in-flight shard the caller is actively using (which is
//! never evicted out from under it; entries are `Arc`s anyway, so an
//! evicted-while-borrowed payload just lives until the borrower
//! drops). `cache_bytes = 0` means unbounded (cache everything — the
//! "local disk twin" mode). Hit/miss/eviction counters flow into the
//! `run_summary` event and `BENCH_pipeline.json`.
//!
//! [`ShardPayload`] is the cached unit: one complete shard file image
//! held in a u64-aligned heap buffer (same alignment trick as the
//! reader's heap fallback) with the header validated and the payload
//! XXH64 **always** verified at construction — for remote bytes this
//! is the verify-on-arrival step, and there is deliberately no
//! `RHO_STORE_NO_VERIFY` escape hatch on this path: bytes that crossed
//! a wire are never trusted unverified.

use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::format::{ShardHeader, HEADER_LEN};
use crate::util::hash::xxh64;

/// Cache observability counters (monotonic over the cache's life).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// One complete shard file image (header + columnar payload) in a
/// u64-aligned heap buffer, validated and checksum-verified at
/// construction. Column accessors mirror `ShardReader`'s.
pub struct ShardPayload {
    words: Vec<u64>,
    len: usize,
    pub rows: usize,
    pub d: usize,
    pub classes: usize,
    pub checksum: u64,
}

impl ShardPayload {
    /// Validate + adopt a full shard file image. `what` names the
    /// source (file path or URL) in errors. The payload XXH64 is
    /// always verified — this is the arrival checkpoint for bytes that
    /// crossed a wire.
    pub fn from_bytes(bytes: &[u8], what: &str) -> Result<ShardPayload> {
        let header = ShardHeader::decode(bytes, std::path::Path::new(what))?;
        let Some(expect) = header.file_len() else {
            bail!("{what}: shard header implies an impossibly large file (corrupt header)");
        };
        if bytes.len() as u64 != expect {
            bail!(
                "{what}: shard is {} bytes but its header implies {expect} \
                 (truncated or trailing garbage)",
                bytes.len()
            );
        }
        let payload = &bytes[HEADER_LEN..];
        let got = xxh64(payload, 0);
        if got != header.checksum {
            bail!(
                "{what}: shard checksum mismatch (header says {:#018x}, payload hashes to \
                 {got:#018x}) — refusing corrupted data",
                header.checksum
            );
        }
        // u64-backed buffer so the xs column (offset 64) stays f32-aligned.
        let mut words = vec![0u64; bytes.len().div_ceil(8)];
        // SAFETY: `words` owns div_ceil(len, 8) * 8 >= bytes.len()
        // bytes of freshly-allocated storage, so the copy is in-bounds
        // and the source slice cannot overlap the new allocation.
        unsafe {
            std::ptr::copy_nonoverlapping(
                bytes.as_ptr(),
                words.as_mut_ptr() as *mut u8,
                bytes.len(),
            );
        }
        Ok(ShardPayload {
            words,
            len: bytes.len(),
            rows: header.rows as usize,
            d: header.d as usize,
            classes: header.classes as usize,
            checksum: header.checksum,
        })
    }

    fn bytes(&self) -> &[u8] {
        // SAFETY: `words` owns >= self.len bytes (from_bytes allocated
        // div_ceil(len, 8) u64 words) and is never mutated after
        // adoption, so the byte view is valid for self's lifetime.
        unsafe { std::slice::from_raw_parts(self.words.as_ptr() as *const u8, self.len) }
    }

    /// All features, row-major.
    pub fn xs(&self) -> &[f32] {
        let b = &self.bytes()[HEADER_LEN..HEADER_LEN + self.rows * self.d * 4];
        // SAFETY: `b` starts at byte 64 of a u64-aligned base, so it is
        // 4-byte aligned; its length is exactly rows * d * 4 validated
        // bytes, and every 4-byte pattern is a valid f32.
        unsafe { std::slice::from_raw_parts(b.as_ptr() as *const f32, self.rows * self.d) }
    }

    /// One row's features.
    pub fn x(&self, i: usize) -> &[f32] {
        &self.xs()[i * self.d..(i + 1) * self.d]
    }

    /// All labels.
    pub fn ys(&self) -> &[u8] {
        let start = HEADER_LEN + self.rows * self.d * 4;
        &self.bytes()[start..start + self.rows * 4]
    }

    /// One row's label.
    pub fn y(&self, i: usize) -> u32 {
        let b = self.ys();
        u32::from_le_bytes(b[i * 4..i * 4 + 4].try_into().expect("4 bytes"))
    }

    /// One row's packed meta byte.
    pub fn meta(&self, i: usize) -> u8 {
        let start = HEADER_LEN + self.rows * self.d * 4 + self.rows * 4;
        self.bytes()[start + i]
    }

    /// Heap footprint of this payload.
    pub fn nbytes(&self) -> u64 {
        (self.words.len() * 8) as u64
    }
}

struct Entry {
    data: Arc<ShardPayload>,
    bytes: u64,
    last_used: u64,
}

struct Inner {
    map: HashMap<u32, Entry>,
    bytes: u64,
    tick: u64,
    evictions: u64,
}

/// Bounded LRU cache of shard payloads, keyed by shard index within
/// one split. Thread-safe: the producer's gather and the engine's
/// prefetcher thread share it.
pub struct ShardCache {
    cap_bytes: u64,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ShardCache {
    /// `cap_bytes = 0` means unbounded.
    pub fn new(cap_bytes: u64) -> ShardCache {
        ShardCache {
            cap_bytes,
            inner: Mutex::new(Inner { map: HashMap::new(), bytes: 0, tick: 0, evictions: 0 }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    pub fn cap_bytes(&self) -> u64 {
        self.cap_bytes
    }

    /// Look up shard `k`, bumping its recency. Counts a hit or miss.
    pub fn get(&self, k: u32) -> Option<Arc<ShardPayload>> {
        let mut inner = self.inner.lock().expect("shard cache poisoned");
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(&k) {
            Some(e) => {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(Arc::clone(&e.data))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert shard `k`, evicting LRU entries (never `k` itself) until
    /// the *other* residents fit under the cap — so post-insert
    /// residency is ≤ cap + this one in-flight shard. Returns the
    /// cached `Arc` (the existing entry wins a double-insert race).
    pub fn insert(&self, k: u32, payload: ShardPayload) -> Arc<ShardPayload> {
        let bytes = payload.nbytes();
        let data = Arc::new(payload);
        let mut inner = self.inner.lock().expect("shard cache poisoned");
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(e) = inner.map.get_mut(&k) {
            e.last_used = tick;
            return Arc::clone(&e.data);
        }
        inner.map.insert(k, Entry { data: Arc::clone(&data), bytes, last_used: tick });
        inner.bytes += bytes;
        if self.cap_bytes > 0 {
            while inner.bytes.saturating_sub(bytes) > self.cap_bytes && inner.map.len() > 1 {
                // Ties on last_used are real (touch() stamps a whole
                // prefetch window with one tick); break them by
                // smallest key so the evicted shard — and every
                // downstream hit/miss counter that lands in the event
                // ledger — is identical across runs instead of
                // HashMap-iteration-order dependent.
                let victim = inner
                    .map
                    .iter()
                    .filter(|(&key, _)| key != k)
                    .min_by_key(|&(&key, e)| (e.last_used, key))
                    .map(|(&key, _)| key)
                    .expect("len > 1 so a victim exists");
                let gone = inner.map.remove(&victim).expect("victim present");
                inner.bytes -= gone.bytes;
                inner.evictions += 1;
            }
        }
        data
    }

    /// Presence check that counts no hit/miss and bumps no recency —
    /// for the prefetcher, whose probes are not gather traffic.
    pub fn contains(&self, k: u32) -> bool {
        self.inner.lock().expect("shard cache poisoned").map.contains_key(&k)
    }

    /// Bump the recency of `keys` without counting hits — the windowed
    /// -eviction hook: the prefetcher marks the sampler's upcoming
    /// window hot so eviction pressure lands on shards the shuffle has
    /// already left behind, not ones about to be gathered.
    pub fn touch(&self, keys: &[u32]) {
        let mut inner = self.inner.lock().expect("shard cache poisoned");
        inner.tick += 1;
        let tick = inner.tick;
        for k in keys {
            if let Some(e) = inner.map.get_mut(k) {
                e.last_used = tick;
            }
        }
    }

    /// Resident payload bytes right now.
    pub fn bytes(&self) -> u64 {
        self.inner.lock().expect("shard cache poisoned").bytes
    }

    /// Resident shard count right now.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("shard cache poisoned").map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.inner.lock().expect("shard cache poisoned").evictions,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::store::format::encode_shard;
    use crate::util::prop;

    fn payload(rows: usize, d: usize, salt: f32) -> ShardPayload {
        let xs: Vec<f32> = (0..rows * d).map(|i| i as f32 + salt).collect();
        let ys: Vec<u32> = (0..rows as u32).map(|i| i % 3).collect();
        let meta = vec![0u8; rows];
        ShardPayload::from_bytes(&encode_shard(d, 3, &xs, &ys, &meta), "mem").unwrap()
    }

    #[test]
    fn payload_columns_match_encoded_shard() {
        let p = payload(5, 3, 0.5);
        assert_eq!((p.rows, p.d, p.classes), (5, 3, 3));
        assert_eq!(p.x(2), &[6.5f32, 7.5, 8.5]);
        assert_eq!(p.y(4), 1);
        assert_eq!(p.meta(0), 0);
    }

    #[test]
    fn payload_refuses_corruption_and_truncation() {
        let img = encode_shard(3, 3, &[1.0; 12], &[0, 1, 2, 0], &[0; 4]);
        let mut bad = img.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x10;
        let err = ShardPayload::from_bytes(&bad, "http://h/s.rsd").unwrap_err().to_string();
        assert!(err.contains("checksum") && err.contains("http://h/s.rsd"), "{err}");
        assert!(ShardPayload::from_bytes(&img[..img.len() - 2], "m").is_err());
        let mut bad = img.clone();
        bad[0] = b'X';
        assert!(ShardPayload::from_bytes(&bad, "m").is_err());
    }

    #[test]
    fn lru_cache_never_exceeds_cap_plus_inflight() {
        // property: at every point of a random workload, resident
        // bytes ≤ cap + the largest single payload
        prop::check("cache-bounded", 25, |rng| {
            let one = payload(4, 2, 0.0).nbytes();
            let cap = one * (1 + rng.below(4) as u64); // 1..=4 shards
            let cache = ShardCache::new(cap);
            for _ in 0..60 {
                let k = rng.below(12) as u32;
                if cache.get(k).is_none() {
                    cache.insert(k, payload(4, 2, k as f32));
                }
                if cache.bytes() > cap + one {
                    return Err(format!("resident {} > cap {cap} + {one}", cache.bytes()));
                }
                if cache.len() as u64 * one != cache.bytes() {
                    return Err("byte accounting drifted".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn hit_after_insert_and_counters() {
        prop::check("cache-hit-after-insert", 25, |rng| {
            let one = payload(4, 2, 0.0).nbytes();
            // cap = 3 shards → steady state holds 4 (cap + the
            // in-flight shard an insert is allowed to overshoot by)
            let cache = ShardCache::new(one * 3);
            let mut resident: Vec<u32> = Vec::new();
            for _ in 0..40 {
                let k = rng.below(8) as u32;
                let before = cache.stats();
                match cache.get(k) {
                    Some(p) => {
                        if !resident.contains(&k) {
                            return Err(format!("hit on {k} which should be evicted/absent"));
                        }
                        if cache.stats().hits != before.hits + 1 {
                            return Err("hit not counted".into());
                        }
                        // content sanity: the payload is the one inserted for k
                        if p.x(0)[0] != k as f32 {
                            return Err("wrong payload for key".into());
                        }
                    }
                    None => {
                        if cache.stats().misses != before.misses + 1 {
                            return Err("miss not counted".into());
                        }
                        let p = cache.insert(k, payload(4, 2, k as f32));
                        if p.x(0)[0] != k as f32 {
                            return Err("insert returned wrong payload".into());
                        }
                        // immediate re-get must hit
                        if cache.get(k).is_none() {
                            return Err(format!("no hit immediately after inserting {k}"));
                        }
                        resident.push(k);
                        while resident.len() > 4 {
                            resident.remove(0);
                        }
                    }
                }
                // model `resident` as LRU order: move k to the back
                if let Some(pos) = resident.iter().position(|&r| r == k) {
                    let v = resident.remove(pos);
                    resident.push(v);
                }
            }
            Ok(())
        });
    }

    #[test]
    fn eviction_order_is_least_recently_used() {
        let one = payload(4, 2, 0.0).nbytes();
        let cache = ShardCache::new(one); // cap = 1 shard, +1 in flight
        cache.insert(0, payload(4, 2, 0.0));
        cache.insert(1, payload(4, 2, 1.0));
        assert!(cache.get(0).is_some()); // 0 now more recent than 1
        cache.insert(2, payload(4, 2, 2.0)); // evicts 1, the LRU
        assert!(cache.get(1).is_none(), "LRU entry 1 should be evicted");
        assert!(cache.get(0).is_some());
        assert!(cache.get(2).is_some());
        assert_eq!(cache.stats().evictions, 1);
    }

    #[test]
    fn touch_protects_the_upcoming_window() {
        let one = payload(4, 2, 0.0).nbytes();
        let cache = ShardCache::new(one); // cap = 1 shard, +1 in flight
        cache.insert(0, payload(4, 2, 0.0));
        cache.insert(1, payload(4, 2, 1.0));
        cache.touch(&[0]); // 0 is in the upcoming window → protected
        cache.insert(2, payload(4, 2, 2.0));
        assert!(cache.get(0).is_some(), "touched shard survived");
        assert!(cache.get(1).is_none(), "untouched shard evicted");
    }

    #[test]
    fn eviction_ties_break_by_key_deterministically() {
        // touch() stamps several residents with one tick; the victim
        // among the tied set must be the smallest key, every run.
        let one = payload(4, 2, 0.0).nbytes();
        for _ in 0..8 {
            let cache = ShardCache::new(one * 2); // cap = 2, +1 in flight
            cache.insert(9, payload(4, 2, 9.0));
            cache.insert(5, payload(4, 2, 5.0));
            cache.touch(&[9, 5]); // 9 and 5 now tie on last_used
            cache.insert(7, payload(4, 2, 7.0)); // must evict 5, never 9
            assert!(cache.contains(9), "tie must evict the smaller key (5), not 9");
            assert!(!cache.contains(5), "smaller tied key 5 should be the victim");
            assert!(cache.contains(7));
        }
    }

    #[test]
    fn zero_cap_is_unbounded() {
        let cache = ShardCache::new(0);
        for k in 0..10 {
            cache.insert(k, payload(4, 2, k as f32));
        }
        assert_eq!(cache.len(), 10);
        assert_eq!(cache.stats().evictions, 0);
    }
}
