//! The versioned binary shard format (and its IL-sidecar sibling).
//!
//! One shard file (`shard-NNNNN.rsd`) is a fixed 64-byte header
//! followed by a columnar payload:
//!
//! ```text
//! offset  size          field
//! 0       8             magic  "RHOSHARD"
//! 8       4             format version (u32 LE, currently 1)
//! 12      4             d        — feature dim (u32 LE)
//! 16      4             classes  (u32 LE)
//! 20      8             rows     (u64 LE, > 0)
//! 28      8             XXH64 of the payload (seed 0, u64 LE)
//! 36      28            reserved (zero)
//! 64      rows*d*4      xs   — row-major f32 LE features
//! ...     rows*4        ys   — u32 LE labels
//! ...     rows*1        meta — packed PointMeta flag bytes
//! ```
//!
//! The header is 64 bytes so every column is at least 4-byte aligned
//! from any page-aligned mapping base — that alignment is what lets
//! [`ShardReader`](super::reader::ShardReader) hand out `&[f32]` /
//! `&[u32]` slices straight over the mapped region (zero copy). The
//! checksum covers the whole payload; readers refuse a shard whose
//! hash, magic, version, or byte length disagrees with the header —
//! corruption and format drift are hard errors, never silent skips.
//!
//! An IL sidecar (`shard-NNNNN.il`) carries one precomputed
//! irreducible-loss f32 per row of its shard, in row order, behind the
//! same magic/version/rows/checksum discipline (32-byte header).

use anyhow::{bail, Result};
use std::path::{Path, PathBuf};

use crate::data::PointMeta;
use crate::util::hash::xxh64;

pub const SHARD_MAGIC: &[u8; 8] = b"RHOSHARD";
pub const SHARD_VERSION: u32 = 1;
pub const HEADER_LEN: usize = 64;

pub const SIDECAR_MAGIC: &[u8; 8] = b"RHOILSCR";
pub const SIDECAR_VERSION: u32 = 1;
pub const SIDECAR_HEADER_LEN: usize = 32;

/// File name of shard `i` within a split directory.
pub fn shard_file_name(i: usize) -> String {
    format!("shard-{i:05}.rsd")
}

/// The IL-sidecar path that belongs to a shard file.
pub fn sidecar_path(shard: &Path) -> PathBuf {
    shard.with_extension("il")
}

/// Pack ground-truth provenance flags into the on-disk meta byte.
pub fn pack_meta(m: PointMeta) -> u8 {
    u8::from(m.noisy)
        | (u8::from(m.low_relevance) << 1)
        | (u8::from(m.duplicate) << 2)
        | (u8::from(m.ambiguous) << 3)
}

pub fn unpack_meta(b: u8) -> PointMeta {
    PointMeta {
        noisy: b & 1 != 0,
        low_relevance: b & 2 != 0,
        duplicate: b & 4 != 0,
        ambiguous: b & 8 != 0,
    }
}

/// Decoded shard header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardHeader {
    pub d: u32,
    pub classes: u32,
    pub rows: u64,
    pub checksum: u64,
}

impl ShardHeader {
    /// Payload byte length implied by the header. `None` when the
    /// header's `rows`/`d` would overflow — header fields are not
    /// covered by the payload checksum, so a corrupt/crafted header
    /// must fail here with a named error, not wrap in release builds
    /// and alias a plausible length.
    pub fn payload_len(&self) -> Option<u64> {
        let rows = self.rows;
        let xs = rows.checked_mul(self.d as u64)?.checked_mul(4)?;
        xs.checked_add(rows.checked_mul(4)?)?.checked_add(rows)
    }

    /// Total file length implied by the header (`None` on overflow).
    pub fn file_len(&self) -> Option<u64> {
        self.payload_len()?.checked_add(HEADER_LEN as u64)
    }

    pub fn encode(&self) -> [u8; HEADER_LEN] {
        let mut h = [0u8; HEADER_LEN];
        h[0..8].copy_from_slice(SHARD_MAGIC);
        h[8..12].copy_from_slice(&SHARD_VERSION.to_le_bytes());
        h[12..16].copy_from_slice(&self.d.to_le_bytes());
        h[16..20].copy_from_slice(&self.classes.to_le_bytes());
        h[20..28].copy_from_slice(&self.rows.to_le_bytes());
        h[28..36].copy_from_slice(&self.checksum.to_le_bytes());
        h
    }

    /// Decode and structurally validate a header. `what` names the file
    /// in errors.
    pub fn decode(bytes: &[u8], what: &Path) -> Result<ShardHeader> {
        if bytes.len() < HEADER_LEN {
            bail!("{what:?}: {} bytes is too short for a shard header", bytes.len());
        }
        if &bytes[0..8] != SHARD_MAGIC {
            bail!("{what:?} is not a RHO shard (bad magic {:?})", &bytes[0..8]);
        }
        let version = u32::from_le_bytes(bytes[8..12].try_into().expect("4 bytes"));
        if version != SHARD_VERSION {
            bail!(
                "{what:?}: shard format version {version}, this build reads version {SHARD_VERSION} \
                 — re-ingest the store (format versions are never silently coerced)"
            );
        }
        let d = u32::from_le_bytes(bytes[12..16].try_into().expect("4 bytes"));
        let classes = u32::from_le_bytes(bytes[16..20].try_into().expect("4 bytes"));
        let rows = u64::from_le_bytes(bytes[20..28].try_into().expect("8 bytes"));
        let checksum = u64::from_le_bytes(bytes[28..36].try_into().expect("8 bytes"));
        if d == 0 || classes == 0 || rows == 0 {
            bail!("{what:?}: degenerate shard header (d {d}, classes {classes}, rows {rows})");
        }
        Ok(ShardHeader { d, classes, rows, checksum })
    }
}

/// Build one complete shard file image (header + payload) in memory.
/// The writer buffers at most one shard, so `rows` is bounded by its
/// `shard_rows`.
pub fn encode_shard(d: usize, classes: usize, xs: &[f32], ys: &[u32], meta: &[u8]) -> Vec<u8> {
    let rows = ys.len();
    assert_eq!(xs.len(), rows * d, "xs length");
    assert_eq!(meta.len(), rows, "meta length");
    assert!(rows > 0, "empty shard");
    let mut payload = Vec::with_capacity(rows * d * 4 + rows * 4 + rows);
    for &x in xs {
        payload.extend_from_slice(&x.to_le_bytes());
    }
    for &y in ys {
        payload.extend_from_slice(&y.to_le_bytes());
    }
    payload.extend_from_slice(meta);
    let header = ShardHeader {
        d: u32::try_from(d).expect("shard d fits u32"),
        classes: u32::try_from(classes).expect("shard classes fits u32"),
        rows: rows as u64,
        checksum: xxh64(&payload, 0),
    };
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&header.encode());
    out.append(&mut payload);
    out
}

/// Build one complete IL-sidecar file image for a shard's `values`.
pub fn encode_sidecar(values: &[f32]) -> Vec<u8> {
    assert!(!values.is_empty(), "empty sidecar");
    let mut payload = Vec::with_capacity(values.len() * 4);
    for &v in values {
        payload.extend_from_slice(&v.to_le_bytes());
    }
    let mut out = Vec::with_capacity(SIDECAR_HEADER_LEN + payload.len());
    out.extend_from_slice(SIDECAR_MAGIC);
    out.extend_from_slice(&SIDECAR_VERSION.to_le_bytes());
    out.extend_from_slice(&[0u8; 4]);
    out.extend_from_slice(&(values.len() as u64).to_le_bytes());
    out.extend_from_slice(&xxh64(&payload, 0).to_le_bytes());
    debug_assert_eq!(out.len(), SIDECAR_HEADER_LEN);
    out.append(&mut payload);
    out
}

/// Decode + fully validate an IL sidecar; returns the per-row values.
pub fn decode_sidecar(bytes: &[u8], what: &Path) -> Result<Vec<f32>> {
    if bytes.len() < SIDECAR_HEADER_LEN {
        bail!("{what:?}: {} bytes is too short for an IL sidecar", bytes.len());
    }
    if &bytes[0..8] != SIDECAR_MAGIC {
        bail!("{what:?} is not a RHO IL sidecar (bad magic {:?})", &bytes[0..8]);
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().expect("4 bytes"));
    if version != SIDECAR_VERSION {
        bail!("{what:?}: sidecar version {version}, this build reads {SIDECAR_VERSION}");
    }
    let rows = u64::from_le_bytes(bytes[16..24].try_into().expect("8 bytes"));
    let checksum = u64::from_le_bytes(bytes[24..32].try_into().expect("8 bytes"));
    let payload = &bytes[SIDECAR_HEADER_LEN..];
    if rows.checked_mul(4) != Some(payload.len() as u64) {
        bail!("{what:?}: sidecar claims {rows} rows but carries {} payload bytes", payload.len());
    }
    if xxh64(payload, 0) != checksum {
        bail!("{what:?}: sidecar checksum mismatch (corrupted or truncated)");
    }
    Ok(payload.chunks_exact(4).map(|b| f32::from_le_bytes(b.try_into().expect("4 bytes"))).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_byte_round_trips_all_16_combos() {
        for bits in 0u8..16 {
            let m = unpack_meta(bits);
            assert_eq!(pack_meta(m), bits);
        }
        // unknown high bits are dropped on unpack
        assert_eq!(pack_meta(unpack_meta(0xF0)), 0);
    }

    #[test]
    fn header_round_trips() {
        let h = ShardHeader { d: 64, classes: 10, rows: 1234, checksum: 0xDEAD_BEEF_CAFE_F00D };
        let enc = h.encode();
        assert_eq!(enc.len(), HEADER_LEN);
        let dec = ShardHeader::decode(&enc, Path::new("x.rsd")).unwrap();
        assert_eq!(dec, h);
        assert_eq!(h.file_len(), Some((HEADER_LEN + 1234 * 64 * 4 + 1234 * 4 + 1234) as u64));
        // a corrupt/crafted header can't wrap into a plausible length
        let huge = ShardHeader { d: u32::MAX, classes: 2, rows: u64::MAX / 2, checksum: 0 };
        assert_eq!(huge.payload_len(), None);
        assert_eq!(huge.file_len(), None);
    }

    #[test]
    fn header_rejects_bad_magic_version_and_degenerate_dims() {
        let h = ShardHeader { d: 8, classes: 2, rows: 4, checksum: 1 }.encode();
        let mut bad = h;
        bad[0] = b'X';
        assert!(ShardHeader::decode(&bad, Path::new("x")).unwrap_err().to_string().contains("magic"));
        let mut bad = h;
        bad[8] = 99;
        let err = ShardHeader::decode(&bad, Path::new("x")).unwrap_err().to_string();
        assert!(err.contains("version 99"), "{err}");
        let zero_rows = ShardHeader { d: 8, classes: 2, rows: 0, checksum: 1 }.encode();
        assert!(ShardHeader::decode(&zero_rows, Path::new("x")).is_err());
        assert!(ShardHeader::decode(&h[..10], Path::new("x")).is_err());
    }

    #[test]
    fn shard_image_is_self_consistent() {
        let xs = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let ys = [0u32, 2];
        let meta = [pack_meta(PointMeta { noisy: true, ..Default::default() }), 0];
        let img = encode_shard(3, 3, &xs, &ys, &meta);
        let h = ShardHeader::decode(&img, Path::new("s.rsd")).unwrap();
        assert_eq!((h.d, h.classes, h.rows), (3, 3, 2));
        assert_eq!(h.file_len(), Some(img.len() as u64));
        assert_eq!(xxh64(&img[HEADER_LEN..], 0), h.checksum);
    }

    #[test]
    fn sidecar_round_trips_and_refuses_corruption() {
        let vals = [0.5f32, -1.25, 3.5];
        let img = encode_sidecar(&vals);
        assert_eq!(decode_sidecar(&img, Path::new("s.il")).unwrap(), vals);
        let mut bad = img.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x40;
        let err = decode_sidecar(&bad, Path::new("s.il")).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        let mut bad = img.clone();
        bad[8] = 9;
        assert!(decode_sidecar(&bad, Path::new("s.il")).unwrap_err().to_string().contains("version"));
        assert!(decode_sidecar(&img[..img.len() - 4], Path::new("s.il")).is_err());
    }

    #[test]
    fn naming_helpers() {
        assert_eq!(shard_file_name(7), "shard-00007.rsd");
        assert_eq!(sidecar_path(Path::new("a/shard-00007.rsd")), PathBuf::from("a/shard-00007.il"));
    }
}
