//! Dataset substrate: synthetic analogues of the paper's seven
//! benchmarks, with *controlled* noise / redundancy / relevance so the
//! selection-function claims are directly measurable (DESIGN.md §2).

pub mod catalog;
pub mod loader;
pub mod noise;
pub mod sharding;
pub mod store;
pub mod synth;

/// Ground-truth provenance flags for one training point. The paper has
/// to estimate these properties; the synthetic substrate knows them.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PointMeta {
    /// Label was corrupted by a noise injector (uniform or structured).
    pub noisy: bool,
    /// Point belongs to a "low relevance" class (CIFAR100-Relevance).
    pub low_relevance: bool,
    /// Point is a jittered duplicate of another point (redundancy).
    pub duplicate: bool,
    /// Point is an ambiguous prototype mixture (AmbiguousMNIST analogue).
    pub ambiguous: bool,
}

/// A dense in-memory classification dataset (row-major features).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub d: usize,
    pub classes: usize,
    /// len = n * d, row-major.
    pub xs: Vec<f32>,
    pub ys: Vec<u32>,
    pub meta: Vec<PointMeta>,
}

impl Dataset {
    pub fn empty(d: usize, classes: usize) -> Self {
        Dataset { d, classes, xs: Vec::new(), ys: Vec::new(), meta: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.ys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ys.is_empty()
    }

    /// Resident bytes of the dense buffers (features + labels + meta)
    /// — the memory-vs-shards number the `run_summary` event reports.
    pub fn nbytes(&self) -> u64 {
        (self.xs.len() * std::mem::size_of::<f32>()
            + self.ys.len() * std::mem::size_of::<u32>()
            + self.meta.len() * std::mem::size_of::<PointMeta>()) as u64
    }

    /// Feature row of point `i`.
    pub fn x(&self, i: usize) -> &[f32] {
        &self.xs[i * self.d..(i + 1) * self.d]
    }

    pub fn push(&mut self, x: &[f32], y: u32, meta: PointMeta) {
        debug_assert_eq!(x.len(), self.d);
        debug_assert!((y as usize) < self.classes);
        self.xs.extend_from_slice(x);
        self.ys.push(y);
        self.meta.push(meta);
    }

    /// Gather rows into contiguous (features, labels) buffers for the
    /// runtime (labels widened to i32 for the HLO boundary).
    pub fn gather(&self, idx: &[u32]) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(idx.len() * self.d);
        let mut ys = Vec::with_capacity(idx.len());
        for &i in idx {
            xs.extend_from_slice(self.x(i as usize));
            ys.push(self.ys[i as usize] as i32);
        }
        (xs, ys)
    }

    /// Gather into caller-provided buffers (allocation-free hot path).
    pub fn gather_into(&self, idx: &[u32], xs: &mut Vec<f32>, ys: &mut Vec<i32>) {
        xs.clear();
        ys.clear();
        xs.reserve(idx.len() * self.d);
        ys.reserve(idx.len());
        for &i in idx {
            xs.extend_from_slice(self.x(i as usize));
            ys.push(self.ys[i as usize] as i32);
        }
    }

    /// New dataset containing the given rows.
    pub fn subset(&self, idx: &[u32]) -> Dataset {
        let mut out = Dataset::empty(self.d, self.classes);
        for &i in idx {
            out.push(self.x(i as usize), self.ys[i as usize], self.meta[i as usize]);
        }
        out
    }

    /// Split into (first `k`, rest).
    pub fn split_at(&self, k: usize) -> (Dataset, Dataset) {
        let k = k.min(self.len());
        let a: Vec<u32> = (0..k as u32).collect();
        let b: Vec<u32> = (k as u32..self.len() as u32).collect();
        (self.subset(&a), self.subset(&b))
    }

    /// Append all rows of `other` (same d/classes).
    pub fn extend_from(&mut self, other: &Dataset) {
        assert_eq!(self.d, other.d);
        assert_eq!(self.classes, other.classes);
        self.xs.extend_from_slice(&other.xs);
        self.ys.extend_from_slice(&other.ys);
        self.meta.extend_from_slice(&other.meta);
    }

    pub fn frac_noisy(&self) -> f32 {
        if self.is_empty() {
            return 0.0;
        }
        self.meta.iter().filter(|m| m.noisy).count() as f32 / self.len() as f32
    }

    /// Per-class counts (histogram over labels).
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.classes];
        for &y in &self.ys {
            counts[y as usize] += 1;
        }
        counts
    }
}

/// The train/holdout/val/test split for one benchmark. `holdout`
/// trains the IL model (paper §3); `val` selects its best checkpoint
/// (App. B); `test` measures accuracy.
#[derive(Clone, Debug)]
pub struct Bundle {
    pub name: String,
    pub train: Dataset,
    pub holdout: Dataset,
    pub val: Dataset,
    pub test: Dataset,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let mut ds = Dataset::empty(2, 3);
        ds.push(&[0.0, 1.0], 0, PointMeta::default());
        ds.push(&[2.0, 3.0], 1, PointMeta { noisy: true, ..Default::default() });
        ds.push(&[4.0, 5.0], 2, PointMeta::default());
        ds
    }

    #[test]
    fn gather_rows() {
        let ds = tiny();
        let (xs, ys) = ds.gather(&[2, 0]);
        assert_eq!(xs, vec![4.0, 5.0, 0.0, 1.0]);
        assert_eq!(ys, vec![2, 0]);
    }

    #[test]
    fn gather_into_reuses_buffers() {
        let ds = tiny();
        let mut xs = vec![9.0; 100];
        let mut ys = vec![7; 3];
        ds.gather_into(&[1], &mut xs, &mut ys);
        assert_eq!(xs, vec![2.0, 3.0]);
        assert_eq!(ys, vec![1]);
    }

    #[test]
    fn subset_and_split() {
        let ds = tiny();
        let sub = ds.subset(&[1]);
        assert_eq!(sub.len(), 1);
        assert!(sub.meta[0].noisy);
        let (a, b) = ds.split_at(2);
        assert_eq!((a.len(), b.len()), (2, 1));
        assert_eq!(b.ys[0], 2);
    }

    #[test]
    fn counts_and_fractions() {
        let ds = tiny();
        assert_eq!(ds.class_counts(), vec![1, 1, 1]);
        assert!((ds.frac_noisy() - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn nbytes_counts_all_columns() {
        let ds = tiny(); // 3 rows, d=2
        assert_eq!(ds.nbytes(), (6 * 4 + 3 * 4 + 3 * std::mem::size_of::<PointMeta>()) as u64);
        assert_eq!(Dataset::empty(8, 2).nbytes(), 0);
    }
}
