//! Candidate-batch sharding for the parallel scoring pool (paper §3,
//! "Simple parallelized selection"): forward passes scale across
//! workers without the diminishing returns of gradient parallelism, so
//! B_t is split into near-equal contiguous shards, one per worker, and
//! shard sizes are rebalanced from observed worker throughput.
//!
//! [`plan_dispatch`] is the pool's chunk planner: chunk *boundaries*
//! are always the fixed artifact-shaped windows `[k·nb, k·nb + take)`
//! (identical to uniform dispatch, so scores stay bit-identical
//! whatever the rates say), while chunk *counts* per worker follow
//! [`proportional_shards`] over the [`RateEma`] service rates.

/// Split `n` items into `k` contiguous shards whose sizes differ by at
/// most one. Returns (start, len) pairs; empty shards allowed if k > n.
pub fn even_shards(n: usize, k: usize) -> Vec<(usize, usize)> {
    assert!(k > 0);
    let base = n / k;
    let rem = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for i in 0..k {
        let len = base + usize::from(i < rem);
        out.push((start, len));
        start += len;
    }
    out
}

/// Proportional shards from observed worker throughputs (items/sec).
/// Falls back to even shards when rates are degenerate. Every shard
/// gets at least one item while items remain (no starvation).
pub fn proportional_shards(n: usize, rates: &[f64]) -> Vec<(usize, usize)> {
    let k = rates.len();
    assert!(k > 0);
    let total: f64 = rates.iter().filter(|r| r.is_finite() && **r > 0.0).sum();
    if total <= 0.0 {
        return even_shards(n, k);
    }
    // Largest-remainder apportionment.
    let mut sizes = vec![0usize; k];
    let mut fracs: Vec<(f64, usize)> = Vec::with_capacity(k);
    let mut assigned = 0;
    for i in 0..k {
        let r = if rates[i].is_finite() && rates[i] > 0.0 { rates[i] } else { 0.0 };
        let ideal = n as f64 * r / total;
        sizes[i] = ideal.floor() as usize;
        assigned += sizes[i];
        fracs.push((ideal - ideal.floor(), i));
    }
    fracs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));
    let mut rest = n - assigned;
    let mut fi = 0;
    while rest > 0 {
        let (_, i) = fracs[fi % fracs.len()];
        sizes[i] += 1;
        rest -= 1;
        fi += 1;
    }
    // No-starvation guarantee: while items remain to spread, every
    // shard gets at least one — a slow-but-alive worker must never
    // idle. Largest-remainder alone can zero out a shard whose ideal
    // share rounds below one (e.g. rates [1000, 1] at n=10), so top
    // empty shards up from the largest one.
    loop {
        let Some(empty) = sizes.iter().position(|&s| s == 0) else { break };
        let donor = (0..k).max_by_key(|&i| sizes[i]).expect("k > 0");
        if sizes[donor] < 2 {
            break; // fewer items than shards; nothing left to spread
        }
        sizes[donor] -= 1;
        sizes[empty] += 1;
    }
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for &len in &sizes {
        out.push((start, len));
        start += len;
    }
    out
}

/// Exponential moving average of worker rates (rebalancing signal).
pub fn ema_update(rates: &mut [f64], observed: &[f64], alpha: f64) {
    for (r, &o) in rates.iter_mut().zip(observed) {
        if o.is_finite() && o > 0.0 {
            *r = if *r > 0.0 { alpha * o + (1.0 - alpha) * *r } else { o };
        }
    }
}

/// Per-worker EMA service rates (chunks/sec), sampled from dispatch
/// completion timestamps. Starts all-zero, which [`proportional_shards`]
/// treats as "no information yet" and falls back to an even split.
#[derive(Clone, Debug)]
pub struct RateEma {
    rates: Vec<f64>,
    alpha: f64,
}

impl RateEma {
    /// Default smoothing when the caller passes an out-of-range alpha.
    pub const DEFAULT_ALPHA: f64 = 0.3;

    /// `alpha` outside (0, 1] — including NaN — falls back to
    /// [`Self::DEFAULT_ALPHA`] instead of poisoning every subsequent
    /// EMA update.
    pub fn new(workers: usize, alpha: f64) -> RateEma {
        let alpha = if alpha > 0.0 && alpha <= 1.0 { alpha } else { Self::DEFAULT_ALPHA };
        RateEma { rates: vec![0.0; workers], alpha }
    }

    /// Fold one dispatch's observed rates in (zeros/NaN/inf observations
    /// are ignored per worker, so idle workers keep their last estimate).
    pub fn observe(&mut self, observed: &[f64]) {
        ema_update(&mut self.rates, observed, self.alpha);
    }

    pub fn rates(&self) -> &[f64] {
        &self.rates
    }

    /// Overwrite the estimates wholesale (ops/test hook: inject a
    /// hostile or known-skewed rate vector). The vector must name
    /// every worker: this used to zero-pad a short vector, and
    /// [`proportional_shards`] reads a zero rate as "no throughput",
    /// so a hook typo silently starved the real lanes it omitted. A
    /// length mismatch in either direction is now a hard error.
    pub fn set(&mut self, rates: &[f64]) -> Result<(), String> {
        if rates.len() != self.rates.len() {
            return Err(format!(
                "rate vector names {} workers but the pool has {} — refusing to pad/truncate \
                 (zero-padded workers look dead to plan_dispatch and starve real lanes)",
                rates.len(),
                self.rates.len()
            ));
        }
        self.rates.clear();
        self.rates.extend_from_slice(rates);
        Ok(())
    }
}

/// One planned scoring chunk: the candidate window
/// `[start, start + take)` of the batch (row base `chunk * nb`),
/// assigned to `worker`'s request lane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkPlan {
    /// Global chunk index within the dispatch (response routing key).
    pub chunk: usize,
    /// First candidate row of the window.
    pub start: usize,
    /// Real rows in the window (`< nb` only for the ragged tail).
    pub take: usize,
    /// Lane the chunk is sent to.
    pub worker: usize,
}

/// Plan one pool dispatch of `n` candidates through artifact-shaped
/// chunks of `nb` rows across `rates.len()` workers.
///
/// Invariants (property-tested below):
/// - chunk boundaries are exactly the uniform-dispatch boundaries
///   `start = k·nb`, `take = min(nb, n − start)` — rate skew moves
///   chunks *between lanes*, never resizes them, so per-chunk scores
///   are bitwise-independent of the rate vector;
/// - every candidate is covered exactly once;
/// - chunk counts per worker follow [`proportional_shards`] (even
///   split under degenerate rates, no starvation while chunks remain).
pub fn plan_dispatch(n: usize, nb: usize, rates: &[f64]) -> Vec<ChunkPlan> {
    assert!(nb > 0);
    let chunks = n.div_ceil(nb);
    let shards = proportional_shards(chunks, rates);
    let mut out = Vec::with_capacity(chunks);
    for (worker, &(shard_start, shard_len)) in shards.iter().enumerate() {
        for chunk in shard_start..shard_start + shard_len {
            let start = chunk * nb;
            out.push(ChunkPlan { chunk, start, take: nb.min(n - start), worker });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn total_and_contiguous(shards: &[(usize, usize)], n: usize) -> Result<(), String> {
        let mut expect_start = 0;
        for &(s, l) in shards {
            if s != expect_start {
                return Err(format!("gap: shard starts at {s}, expected {expect_start}"));
            }
            expect_start = s + l;
        }
        if expect_start != n {
            return Err(format!("covers {expect_start} of {n}"));
        }
        Ok(())
    }

    #[test]
    fn even_shards_cover_exactly_prop() {
        prop::check("even-shards", 100, |rng| {
            let n = rng.below(10_000);
            let k = 1 + rng.below(32);
            let shards = even_shards(n, k);
            if shards.len() != k {
                return Err("wrong shard count".into());
            }
            total_and_contiguous(&shards, n)?;
            let max = shards.iter().map(|s| s.1).max().unwrap();
            let min = shards.iter().map(|s| s.1).min().unwrap();
            if max - min > 1 {
                return Err(format!("imbalance {max}-{min}"));
            }
            Ok(())
        });
    }

    #[test]
    fn proportional_shards_cover_exactly_prop() {
        prop::check("prop-shards", 100, |rng| {
            let n = rng.below(5_000);
            let k = 1 + rng.below(16);
            let rates: Vec<f64> = (0..k).map(|_| rng.f32() as f64 * 10.0).collect();
            total_and_contiguous(&proportional_shards(n, &rates), n)
        });
    }

    #[test]
    fn proportional_shards_sane_under_hostile_rates_prop() {
        // Sizes always sum to n exactly (no loss, no overflow) even
        // when rates mix zeros, NaNs, and infinities.
        prop::check("prop-shards-hostile", 100, |rng| {
            let n = rng.below(10_000);
            let k = 1 + rng.below(16);
            let rates: Vec<f64> = (0..k)
                .map(|_| match rng.below(5) {
                    0 => 0.0,
                    1 => f64::NAN,
                    2 => f64::INFINITY,
                    _ => rng.f32() as f64 * 100.0,
                })
                .collect();
            let shards = proportional_shards(n, &rates);
            if shards.len() != k {
                return Err("wrong shard count".into());
            }
            total_and_contiguous(&shards, n)?;
            if shards.iter().any(|s| s.1 > n) {
                return Err("shard larger than n".into());
            }
            Ok(())
        });
    }

    #[test]
    fn degenerate_rates_match_even_shards_prop() {
        // All-degenerate rate vectors must fall back to exactly the
        // even split, for any (n, k).
        prop::check("prop-shards-degenerate", 60, |rng| {
            let n = rng.below(5_000);
            let k = 1 + rng.below(16);
            let rates: Vec<f64> = (0..k)
                .map(|_| if rng.bernoulli(0.5) { 0.0 } else { f64::NAN })
                .collect();
            let got = proportional_shards(n, &rates);
            let want = even_shards(n, k);
            if got != want {
                return Err(format!("fallback mismatch: {got:?} vs {want:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn no_starvation_under_random_rates_prop() {
        // While items remain (n >= k), every shard gets at least one
        // item, however skewed the positive rates are.
        prop::check("prop-shards-no-starvation", 100, |rng| {
            let k = 1 + rng.below(16);
            let n = k + rng.below(5_000);
            let rates: Vec<f64> = (0..k)
                .map(|_| if rng.bernoulli(0.3) { 0.0 } else { (rng.f32() as f64) * 1e3 + 1e-3 })
                .collect();
            let shards = proportional_shards(n, &rates);
            total_and_contiguous(&shards, n)?;
            if let Some(pos) = shards.iter().position(|s| s.1 == 0) {
                return Err(format!("worker {pos} starved: {shards:?} rates {rates:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn extreme_skew_does_not_starve() {
        // The concrete failure largest-remainder alone exhibits.
        let shards = proportional_shards(10, &[1000.0, 1.0]);
        assert_eq!(shards.iter().map(|s| s.1).sum::<usize>(), 10);
        assert!(shards.iter().all(|s| s.1 >= 1), "{shards:?}");
    }

    #[test]
    fn proportional_tracks_rates() {
        let shards = proportional_shards(1000, &[1.0, 3.0]);
        assert_eq!(shards[0].1 + shards[1].1, 1000);
        assert!((shards[1].1 as f64 - 750.0).abs() <= 1.0, "{shards:?}");
    }

    #[test]
    fn degenerate_rates_fall_back_to_even() {
        let shards = proportional_shards(100, &[0.0, f64::NAN, 0.0, 0.0]);
        assert_eq!(shards.iter().map(|s| s.1).collect::<Vec<_>>(), vec![25, 25, 25, 25]);
    }

    #[test]
    fn ema_moves_toward_observation() {
        let mut rates = vec![10.0, 0.0];
        ema_update(&mut rates, &[20.0, 5.0], 0.5);
        assert_eq!(rates, vec![15.0, 5.0]);
    }

    #[test]
    fn rate_ema_sanitizes_hostile_alpha() {
        for bad in [f64::NAN, 0.0, -1.0, 2.0, f64::INFINITY] {
            let mut ema = RateEma::new(2, bad);
            ema.observe(&[10.0, 10.0]);
            ema.observe(&[20.0, 20.0]);
            assert!(
                ema.rates().iter().all(|r| r.is_finite() && *r > 0.0),
                "alpha {bad} poisoned rates: {:?}",
                ema.rates()
            );
        }
    }

    #[test]
    fn rate_ema_ignores_degenerate_observations() {
        let mut ema = RateEma::new(3, 0.5);
        assert_eq!(ema.rates(), &[0.0, 0.0, 0.0]);
        ema.observe(&[10.0, f64::NAN, 0.0]);
        assert_eq!(ema.rates(), &[10.0, 0.0, 0.0]);
        ema.observe(&[20.0, 4.0, f64::INFINITY]);
        assert_eq!(ema.rates(), &[15.0, 4.0, 0.0]);
    }

    #[test]
    fn rate_ema_set_rejects_length_mismatch() {
        let mut ema = RateEma::new(3, 0.5);
        // a short injected vector must NOT silently zero-pad (padded
        // workers would look dead to plan_dispatch and starve)
        let err = ema.set(&[1.0, 2.0]).expect_err("short vector accepted");
        assert!(err.contains("2 workers") && err.contains("3"), "unhelpful error: {err}");
        assert_eq!(ema.rates(), &[0.0, 0.0, 0.0], "failed set must not mutate");
        // a long vector must not silently truncate either
        assert!(ema.set(&[1.0, 2.0, 3.0, 4.0]).is_err());
        // exact length overwrites wholesale
        ema.set(&[1.0, 2.0, 3.0]).unwrap();
        assert_eq!(ema.rates(), &[1.0, 2.0, 3.0]);
    }

    fn hostile_rates(rng: &mut crate::util::rng::Pcg32, k: usize) -> Vec<f64> {
        (0..k)
            .map(|_| match rng.below(6) {
                0 => 0.0,
                1 => f64::NAN,
                2 => f64::INFINITY,
                3 => 1e-12,
                4 => 1e12,
                _ => rng.f32() as f64 * 100.0,
            })
            .collect()
    }

    #[test]
    fn plan_dispatch_covers_every_candidate_exactly_once_prop() {
        // Satellite guarantee, part 1: under hostile/degenerate EMA
        // rates, the union of planned windows is a disjoint cover of
        // [0, n) and every global chunk index appears exactly once.
        prop::check("plan-dispatch-cover", 150, |rng| {
            let nb = 1 + rng.below(320);
            let n = rng.below(10_000);
            let k = 1 + rng.below(16);
            let rates = hostile_rates(rng, k);
            let plan = plan_dispatch(n, nb, &rates);
            let chunks = n.div_ceil(nb);
            if plan.len() != chunks {
                return Err(format!("{} chunks planned, want {chunks}", plan.len()));
            }
            let mut covered = vec![0u8; n];
            let mut seen_chunk = vec![false; chunks];
            for c in &plan {
                if c.worker >= k {
                    return Err(format!("bogus worker {}", c.worker));
                }
                if seen_chunk[c.chunk] {
                    return Err(format!("chunk {} planned twice", c.chunk));
                }
                seen_chunk[c.chunk] = true;
                for i in c.start..c.start + c.take {
                    covered[i] += 1;
                }
            }
            if covered.iter().any(|&c| c != 1) {
                return Err("a candidate was scored zero or multiple times".into());
            }
            Ok(())
        });
    }

    #[test]
    fn plan_dispatch_boundaries_match_uniform_dispatch_prop() {
        // Satellite guarantee, part 2: chunk windows are byte-for-byte
        // the uniform-dispatch windows regardless of the rate vector —
        // the precondition for bitwise-equal scores (each fixed window
        // is scored by the same deterministic executable wherever it
        // lands).
        prop::check("plan-dispatch-uniform-boundaries", 150, |rng| {
            let nb = 1 + rng.below(320);
            let n = rng.below(10_000);
            let k = 1 + rng.below(16);
            let rates = hostile_rates(rng, k);
            for c in plan_dispatch(n, nb, &rates) {
                if c.start != c.chunk * nb {
                    return Err(format!("chunk {} starts at {}", c.chunk, c.start));
                }
                if c.take != nb.min(n - c.start) {
                    return Err(format!("chunk {} resized to {}", c.chunk, c.take));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn plan_dispatch_tracks_rates_and_respects_no_starvation() {
        // 10 chunks, 4x rate skew: the fast lane gets ~4x the chunks.
        let plan = plan_dispatch(3200, 320, &[4.0, 1.0]);
        let per: Vec<usize> =
            (0..2).map(|w| plan.iter().filter(|c| c.worker == w).count()).collect();
        assert_eq!(per.iter().sum::<usize>(), 10);
        assert_eq!(per, vec![8, 2]);
        // all-degenerate rates fall back to the even split
        let plan = plan_dispatch(3200, 320, &[0.0, f64::NAN]);
        let per: Vec<usize> =
            (0..2).map(|w| plan.iter().filter(|c| c.worker == w).count()).collect();
        assert_eq!(per, vec![5, 5]);
        // extreme skew still feeds the slow lane (rate probe)
        let plan = plan_dispatch(3200, 320, &[1e9, 1e-9]);
        assert!(plan.iter().any(|c| c.worker == 1), "slow lane starved");
    }
}
