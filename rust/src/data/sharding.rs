//! Candidate-batch sharding for the parallel scoring pool (paper §3,
//! "Simple parallelized selection"): forward passes scale across
//! workers without the diminishing returns of gradient parallelism, so
//! B_t is split into near-equal contiguous shards, one per worker, and
//! shard sizes are rebalanced from observed worker throughput.

/// Split `n` items into `k` contiguous shards whose sizes differ by at
/// most one. Returns (start, len) pairs; empty shards allowed if k > n.
pub fn even_shards(n: usize, k: usize) -> Vec<(usize, usize)> {
    assert!(k > 0);
    let base = n / k;
    let rem = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for i in 0..k {
        let len = base + usize::from(i < rem);
        out.push((start, len));
        start += len;
    }
    out
}

/// Proportional shards from observed worker throughputs (items/sec).
/// Falls back to even shards when rates are degenerate. Every shard
/// gets at least one item while items remain (no starvation).
pub fn proportional_shards(n: usize, rates: &[f64]) -> Vec<(usize, usize)> {
    let k = rates.len();
    assert!(k > 0);
    let total: f64 = rates.iter().filter(|r| r.is_finite() && **r > 0.0).sum();
    if total <= 0.0 {
        return even_shards(n, k);
    }
    // Largest-remainder apportionment.
    let mut sizes = vec![0usize; k];
    let mut fracs: Vec<(f64, usize)> = Vec::with_capacity(k);
    let mut assigned = 0;
    for i in 0..k {
        let r = if rates[i].is_finite() && rates[i] > 0.0 { rates[i] } else { 0.0 };
        let ideal = n as f64 * r / total;
        sizes[i] = ideal.floor() as usize;
        assigned += sizes[i];
        fracs.push((ideal - ideal.floor(), i));
    }
    fracs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));
    let mut rest = n - assigned;
    let mut fi = 0;
    while rest > 0 {
        let (_, i) = fracs[fi % fracs.len()];
        sizes[i] += 1;
        rest -= 1;
        fi += 1;
    }
    // No-starvation guarantee: while items remain to spread, every
    // shard gets at least one — a slow-but-alive worker must never
    // idle. Largest-remainder alone can zero out a shard whose ideal
    // share rounds below one (e.g. rates [1000, 1] at n=10), so top
    // empty shards up from the largest one.
    loop {
        let Some(empty) = sizes.iter().position(|&s| s == 0) else { break };
        let donor = (0..k).max_by_key(|&i| sizes[i]).expect("k > 0");
        if sizes[donor] < 2 {
            break; // fewer items than shards; nothing left to spread
        }
        sizes[donor] -= 1;
        sizes[empty] += 1;
    }
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for &len in &sizes {
        out.push((start, len));
        start += len;
    }
    out
}

/// Exponential moving average of worker rates (rebalancing signal).
pub fn ema_update(rates: &mut [f64], observed: &[f64], alpha: f64) {
    for (r, &o) in rates.iter_mut().zip(observed) {
        if o.is_finite() && o > 0.0 {
            *r = if *r > 0.0 { alpha * o + (1.0 - alpha) * *r } else { o };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn total_and_contiguous(shards: &[(usize, usize)], n: usize) -> Result<(), String> {
        let mut expect_start = 0;
        for &(s, l) in shards {
            if s != expect_start {
                return Err(format!("gap: shard starts at {s}, expected {expect_start}"));
            }
            expect_start = s + l;
        }
        if expect_start != n {
            return Err(format!("covers {expect_start} of {n}"));
        }
        Ok(())
    }

    #[test]
    fn even_shards_cover_exactly_prop() {
        prop::check("even-shards", 100, |rng| {
            let n = rng.below(10_000);
            let k = 1 + rng.below(32);
            let shards = even_shards(n, k);
            if shards.len() != k {
                return Err("wrong shard count".into());
            }
            total_and_contiguous(&shards, n)?;
            let max = shards.iter().map(|s| s.1).max().unwrap();
            let min = shards.iter().map(|s| s.1).min().unwrap();
            if max - min > 1 {
                return Err(format!("imbalance {max}-{min}"));
            }
            Ok(())
        });
    }

    #[test]
    fn proportional_shards_cover_exactly_prop() {
        prop::check("prop-shards", 100, |rng| {
            let n = rng.below(5_000);
            let k = 1 + rng.below(16);
            let rates: Vec<f64> = (0..k).map(|_| rng.f32() as f64 * 10.0).collect();
            total_and_contiguous(&proportional_shards(n, &rates), n)
        });
    }

    #[test]
    fn proportional_shards_sane_under_hostile_rates_prop() {
        // Sizes always sum to n exactly (no loss, no overflow) even
        // when rates mix zeros, NaNs, and infinities.
        prop::check("prop-shards-hostile", 100, |rng| {
            let n = rng.below(10_000);
            let k = 1 + rng.below(16);
            let rates: Vec<f64> = (0..k)
                .map(|_| match rng.below(5) {
                    0 => 0.0,
                    1 => f64::NAN,
                    2 => f64::INFINITY,
                    _ => rng.f32() as f64 * 100.0,
                })
                .collect();
            let shards = proportional_shards(n, &rates);
            if shards.len() != k {
                return Err("wrong shard count".into());
            }
            total_and_contiguous(&shards, n)?;
            if shards.iter().any(|s| s.1 > n) {
                return Err("shard larger than n".into());
            }
            Ok(())
        });
    }

    #[test]
    fn degenerate_rates_match_even_shards_prop() {
        // All-degenerate rate vectors must fall back to exactly the
        // even split, for any (n, k).
        prop::check("prop-shards-degenerate", 60, |rng| {
            let n = rng.below(5_000);
            let k = 1 + rng.below(16);
            let rates: Vec<f64> = (0..k)
                .map(|_| if rng.bernoulli(0.5) { 0.0 } else { f64::NAN })
                .collect();
            let got = proportional_shards(n, &rates);
            let want = even_shards(n, k);
            if got != want {
                return Err(format!("fallback mismatch: {got:?} vs {want:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn no_starvation_under_random_rates_prop() {
        // While items remain (n >= k), every shard gets at least one
        // item, however skewed the positive rates are.
        prop::check("prop-shards-no-starvation", 100, |rng| {
            let k = 1 + rng.below(16);
            let n = k + rng.below(5_000);
            let rates: Vec<f64> = (0..k)
                .map(|_| if rng.bernoulli(0.3) { 0.0 } else { (rng.f32() as f64) * 1e3 + 1e-3 })
                .collect();
            let shards = proportional_shards(n, &rates);
            total_and_contiguous(&shards, n)?;
            if let Some(pos) = shards.iter().position(|s| s.1 == 0) {
                return Err(format!("worker {pos} starved: {shards:?} rates {rates:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn extreme_skew_does_not_starve() {
        // The concrete failure largest-remainder alone exhibits.
        let shards = proportional_shards(10, &[1000.0, 1.0]);
        assert_eq!(shards.iter().map(|s| s.1).sum::<usize>(), 10);
        assert!(shards.iter().all(|s| s.1 >= 1), "{shards:?}");
    }

    #[test]
    fn proportional_tracks_rates() {
        let shards = proportional_shards(1000, &[1.0, 3.0]);
        assert_eq!(shards[0].1 + shards[1].1, 1000);
        assert!((shards[1].1 as f64 - 750.0).abs() <= 1.0, "{shards:?}");
    }

    #[test]
    fn degenerate_rates_fall_back_to_even() {
        let shards = proportional_shards(100, &[0.0, f64::NAN, 0.0, 0.0]);
        assert_eq!(shards.iter().map(|s| s.1).collect::<Vec<_>>(), vec![25, 25, 25, 25]);
    }

    #[test]
    fn ema_moves_toward_observation() {
        let mut rates = vec![10.0, 0.0];
        ema_update(&mut rates, &[20.0, 5.0], 0.5);
        assert_eq!(rates, vec![15.0, 5.0]);
    }
}
