//! Finding type + deterministic rendering for the lint pass.

/// One rule violation, anchored to a file (and line, when the rule is
/// line-scoped; tree-level rules such as the inventory and schema
/// cross-checks report line 0).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl Finding {
    pub fn new(file: &str, line: usize, rule: &'static str, message: impl Into<String>) -> Finding {
        Finding { file: file.to_string(), line, rule, message: message.into() }
    }
}

/// Sort findings into their stable report order (file, line, rule,
/// message) — the same bytes on every run, so CI diffs are meaningful.
pub fn sort(findings: &mut Vec<Finding>) {
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule, a.message.as_str())
            .cmp(&(b.file.as_str(), b.line, b.rule, b.message.as_str()))
    });
}

/// Render findings one per line, `file:line: [rule] message`.
pub fn render(findings: &[Finding]) -> String {
    let mut out = String::new();
    for f in findings {
        out.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
    }
    out
}
