//! Static analysis for the invariants the repro's guarantees rest on.
//!
//! Everything this crate promises about RHO-LOSS selection — bitwise
//! identical curves under worker counts, rate skew, speculation,
//! faults, remote stores, and tenant contention — reduces to a small
//! set of hand-maintained source invariants: no wall-clock or
//! hash-order nondeterminism in score/checkpoint/event paths, audited
//! `unsafe`, checked arithmetic in the byte-format parsers, one lock
//! hierarchy, and an event schema that actually covers what CI
//! asserts. This module machine-checks all five, std-only (no `syn`,
//! no `regex` — the vendored-crate constraint), and runs as both the
//! `rho lint` subcommand and the tier-1 `static_lint` test.
//!
//! - [`lexer`] — line scanner that separates code, string literals,
//!   and comments (multi-line aware), so rules never fire on text.
//! - [`manifest`] — rule scopes plus the two committed manifests
//!   (`analysis/unsafe_inventory.txt`, `analysis/lock_order.txt`).
//! - [`rules`] — the five rule families and the tree walk.
//! - [`report`] — findings and their stable rendering.

pub mod lexer;
pub mod manifest;
pub mod report;
pub mod rules;

pub use report::Finding;
pub use rules::{extract_ci_keys, lint_source, lint_tree, schema_missing, unsafe_census};
