//! Line-oriented Rust source scanner for the lint pass.
//!
//! Std-only by design (the vendored-crate constraint rules out `syn`
//! and `regex`): each source line is split into the *code* channel
//! (string-literal bodies blanked, comments removed), the *literal*
//! channel (string contents, for the schema cross-check), and the
//! *comment* channel (for `SAFETY:` and `lint:allow` pragmas). State
//! that spans lines — nested block comments, raw strings, cooked
//! strings continued over a newline — is carried between calls, so
//! multi-line constructs can never leak string contents into the code
//! channel and produce phantom findings.

/// One scanned source line.
pub struct Line {
    /// 1-based line number.
    pub no: usize,
    /// The line with comments removed and string-literal bodies
    /// replaced by `""` — the channel every syntactic rule matches on.
    pub code: String,
    /// String-literal contents (or per-line fragments of multi-line
    /// literals) that appear on this line.
    pub literals: Vec<String>,
    /// Comment text on this line (`//...` tail or block-comment body).
    pub comment: String,
}

#[derive(Clone, Copy, PartialEq)]
enum Carry {
    Code,
    /// Inside a (nestable) `/* ... */`, with nesting depth.
    Block(u32),
    /// Inside `r"..."` / `r#"..."#`, with the hash count.
    Raw(u8),
    /// Inside a `"..."` cooked string (they may span lines).
    Cooked,
}

pub fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// `word` appears in `code` with non-identifier characters (or the
/// string edge) on both sides.
pub fn has_word(code: &str, word: &str) -> bool {
    let cs: Vec<char> = code.chars().collect();
    let ws: Vec<char> = word.chars().collect();
    if ws.is_empty() || cs.len() < ws.len() {
        return false;
    }
    for start in 0..=(cs.len() - ws.len()) {
        if cs[start..start + ws.len()] != ws[..] {
            continue;
        }
        let before_ok = start == 0 || !is_ident(cs[start - 1]);
        let end = start + ws.len();
        let after_ok = end >= cs.len() || !is_ident(cs[end]);
        if before_ok && after_ok {
            return true;
        }
    }
    false
}

/// Scan a whole file into [`Line`]s, carrying multi-line state.
pub fn lex(src: &str) -> Vec<Line> {
    let mut out = Vec::new();
    let mut carry = Carry::Code;
    for (idx, raw) in src.lines().enumerate() {
        let chars: Vec<char> = raw.chars().collect();
        let n = chars.len();
        let mut code = String::new();
        let mut literals = Vec::new();
        let mut comment = String::new();
        let mut lit = String::new();
        let mut i = 0usize;
        while i < n {
            match carry {
                Carry::Block(depth) => {
                    if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                        carry = if depth == 1 { Carry::Code } else { Carry::Block(depth - 1) };
                        i += 2;
                    } else if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                        carry = Carry::Block(depth + 1);
                        i += 2;
                    } else {
                        comment.push(chars[i]);
                        i += 1;
                    }
                }
                Carry::Raw(hashes) => {
                    let h = hashes as usize;
                    let closes = chars[i] == '"'
                        && i + h < n
                        && chars[i + 1..i + 1 + h].iter().all(|&c| c == '#');
                    if closes {
                        literals.push(std::mem::take(&mut lit));
                        code.push_str("\"\"");
                        carry = Carry::Code;
                        i += 1 + h;
                    } else {
                        lit.push(chars[i]);
                        i += 1;
                    }
                }
                Carry::Cooked => match chars[i] {
                    '\\' => {
                        lit.push('?');
                        i += 2;
                    }
                    '"' => {
                        literals.push(std::mem::take(&mut lit));
                        code.push_str("\"\"");
                        carry = Carry::Code;
                        i += 1;
                    }
                    c => {
                        lit.push(c);
                        i += 1;
                    }
                },
                Carry::Code => {
                    let c = chars[i];
                    if c == '/' && i + 1 < n && chars[i + 1] == '/' {
                        comment.push_str(&chars[i..].iter().collect::<String>());
                        i = n;
                    } else if c == '/' && i + 1 < n && chars[i + 1] == '*' {
                        carry = Carry::Block(1);
                        i += 2;
                    } else if c == '"' {
                        carry = Carry::Cooked;
                        i += 1;
                    } else if c == 'r' && (i == 0 || !is_ident(chars[i - 1])) && raw_start(&chars, i).is_some()
                    {
                        let hashes = raw_start(&chars, i).expect("checked");
                        carry = Carry::Raw(hashes);
                        i += 2 + hashes as usize;
                    } else if c == 'b' && i + 1 < n && chars[i + 1] == '"' {
                        // byte string: skip the prefix, let the quote
                        // start a cooked string on the next iteration
                        i += 1;
                    } else if c == '\'' {
                        if i + 1 < n && chars[i + 1] == '\\' {
                            // escaped char literal: skip to closing quote
                            let mut j = i + 2;
                            while j < n && chars[j] != '\'' {
                                j += 1;
                            }
                            i = if j < n { j + 1 } else { n };
                            code.push(' ');
                        } else if i + 2 < n && chars[i + 2] == '\'' {
                            i += 3;
                            code.push(' ');
                        } else {
                            // lifetime
                            code.push(c);
                            i += 1;
                        }
                    } else {
                        code.push(c);
                        i += 1;
                    }
                }
            }
        }
        // A raw/cooked string still open at end of line: bank this
        // line's fragment so the next line starts a fresh one.
        if !lit.is_empty() {
            literals.push(lit);
        }
        out.push(Line { no: idx + 1, code, literals, comment });
    }
    out
}

/// At `chars[i] == 'r'`: if this starts a raw string, return its hash
/// count.
fn raw_start(chars: &[char], i: usize) -> Option<u8> {
    let n = chars.len();
    let mut j = i + 1;
    let mut hashes = 0u8;
    while j < n && chars[j] == '#' {
        hashes = hashes.saturating_add(1);
        j += 1;
    }
    if j < n && chars[j] == '"' {
        Some(hashes)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one(src: &str) -> Line {
        let mut lines = lex(src);
        assert_eq!(lines.len(), 1);
        lines.remove(0)
    }

    #[test]
    fn strips_line_comments_and_strings() {
        let l = one(r#"let x = "HashMap"; // uses Instant::now"#);
        assert_eq!(l.code, r#"let x = ""; "#);
        assert_eq!(l.literals, vec!["HashMap".to_string()]);
        assert!(l.comment.contains("Instant::now"));
    }

    #[test]
    fn raw_strings_are_literals_not_code() {
        let l = one(r##"emit(r#"unsafe { "x" }"#);"##);
        assert_eq!(l.code, r#"emit("");"#);
        assert_eq!(l.literals, vec![r#"unsafe { "x" }"#.to_string()]);
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let l = one(r"fn f<'a>(c: char) -> bool { c == '\'' || c == '{' }");
        assert!(l.code.contains("'a"), "lifetime survives: {}", l.code);
        assert!(!l.code.contains('{') || l.code.matches('{').count() == 1, "{}", l.code);
    }

    #[test]
    fn cooked_string_spans_lines() {
        let lines = lex("bail!(\"first part \\\n  second HashMap part\");\nlet y = 1;");
        assert!(!lines[1].code.contains("HashMap"), "continuation stays literal: {}", lines[1].code);
        assert_eq!(lines[2].code, "let y = 1;");
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let lines = lex("a /* one /* two */ still */ b\n/* open\nSAFETY: here */ c");
        assert_eq!(lines[0].code.trim(), "a  b");
        assert!(lines[2].comment.contains("SAFETY: here"));
        assert_eq!(lines[2].code.trim(), "c");
    }

    #[test]
    fn word_boundaries() {
        assert!(has_word("use HashMap;", "HashMap"));
        assert!(!has_word("n_unsafe += 1", "unsafe"));
        assert!(!has_word("unsafe_lines", "unsafe"));
        assert!(has_word("unsafe {", "unsafe"));
    }
}
