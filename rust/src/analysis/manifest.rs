//! Scope tables and committed-manifest parsing for the lint rules.
//!
//! The scopes below are the policy half of the lint: *which* files
//! must be deterministic, *which* parsers must use checked arithmetic,
//! *which* mutexes participate in the lock hierarchy, and *which*
//! files are allowed to emit event-schema field names. The two
//! committed manifests (`analysis/unsafe_inventory.txt` and
//! `analysis/lock_order.txt`) are the audited half: changing either is
//! a reviewed diff, so new unsafe code or a re-ranked lock cannot
//! slip in silently.

/// Directories walked by `rho lint` and the tier-1 static test,
/// relative to the repo root.
pub const SCAN_DIRS: &[&str] = &["rust/src", "rust/tests", "rust/benches"];

/// Modules whose outputs feed selection decisions, checkpoints, or the
/// event ledger: wall-clock reads and hash-ordered collections here
/// break the bitwise-reproducibility contract (ROADMAP tier-1).
pub const DETERMINISM_SCOPE: &[&str] = &[
    "rust/src/selection/",
    "rust/src/coordinator/engine.rs",
    "rust/src/coordinator/events.rs",
    "rust/src/coordinator/checkpoint.rs",
    "rust/src/coordinator/session.rs",
    "rust/src/coordinator/tracker.rs",
    "rust/src/coordinator/il_model.rs",
];

/// Files where clock reads are legal even when otherwise in scope —
/// throughput metrics, the step timer, and the worker ledger are
/// wall-clock by design.
pub const CLOCK_ALLOWLIST: &[&str] = &[
    "rust/src/util/timer.rs",
    "rust/src/coordinator/metrics.rs",
    "rust/src/runtime/pool.rs",
];

/// Byte-level format parsers: bare narrowing casts and unchecked
/// length/offset arithmetic are findings here (the PR-4/PR-8 rule).
pub const HARDENED: &[&str] = &[
    "rust/src/data/store/format.rs",
    "rust/src/data/store/reader.rs",
    "rust/src/data/store/manifest.rs",
    "rust/src/data/store/remote.rs",
];

/// Files whose mutex acquisitions are checked against the declared
/// hierarchy in `analysis/lock_order.txt`.
pub const LOCK_SCOPE: &[&str] = &["rust/src/runtime/pool.rs", "rust/src/data/store/cache.rs"];

/// Maps a source-line substring to the hierarchy name of the lock it
/// acquires. First match wins, so the more specific aliases lead.
pub const LOCK_ALIASES: &[(&str, &str)] = &[
    ("ledger::", "ledger"),
    ("state()", "ledger"),
    ("stats", "stats"),
    ("rates", "rates"),
    ("health", "health"),
    ("inner", "cache"),
];

/// Files allowed (and expected) to emit event/bench schema field
/// names; the union of their string literals must cover every key the
/// CI python asserts read.
pub const SCHEMA_EMIT: &[&str] = &[
    "rust/src/coordinator/events.rs",
    "rust/benches/bench_pipeline.rs",
    "rust/src/coordinator/scheduler/wire.rs",
    "rust/src/coordinator/scheduler/tenant.rs",
    "rust/src/runtime/pool.rs",
    "rust/src/coordinator/metrics.rs",
    "rust/src/coordinator/scheduler/daemon.rs",
];

/// Cast targets considered narrowing in the hardened parsers.
pub const NARROW: &[&str] = &["u8", "u16", "u32", "i8", "i16", "i32"];

/// Operand-name fragments that mark a `+`/`*` as length/offset
/// arithmetic.
pub const LENISH: &[&str] = &["len", "off", "bytes", "rows", "count", "nbyte"];

/// Committed unsafe inventory, repo-root relative.
pub const UNSAFE_INVENTORY: &str = "analysis/unsafe_inventory.txt";

/// Committed lock hierarchy, repo-root relative.
pub const LOCK_ORDER_FILE: &str = "analysis/lock_order.txt";

/// CI workflow whose python asserts define the consumed schema.
pub const CI_WORKFLOW: &str = ".github/workflows/ci.yml";

/// `rel` equals a scope entry or lives under a `.../`-terminated one.
pub fn in_scope(rel: &str, scopes: &[&str]) -> bool {
    scopes.iter().any(|s| rel == *s || rel.starts_with(s))
}

/// Parse `file:count` inventory lines; `#` comments and blanks skipped.
pub fn parse_inventory(text: &str) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some((file, count)) = line.rsplit_once(':') {
            if let Ok(n) = count.trim().parse::<usize>() {
                out.push((file.trim().to_string(), n));
            }
        }
    }
    out
}

/// Parse the lock hierarchy: one lock name per line, outermost first;
/// `#` comments and blanks skipped.
pub fn parse_lock_order(text: &str) -> Vec<String> {
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(str::to_string)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scope_matching() {
        assert!(in_scope("rust/src/selection/method.rs", DETERMINISM_SCOPE));
        assert!(in_scope("rust/src/coordinator/events.rs", DETERMINISM_SCOPE));
        assert!(!in_scope("rust/src/util/math.rs", DETERMINISM_SCOPE));
    }

    #[test]
    fn inventory_parses_and_skips_comments() {
        let inv = parse_inventory("# audited\nrust/src/a.rs:3\n\nrust/src/b.rs: 11\n");
        assert_eq!(
            inv,
            vec![("rust/src/a.rs".to_string(), 3), ("rust/src/b.rs".to_string(), 11)]
        );
    }

    #[test]
    fn lock_order_parses() {
        assert_eq!(parse_lock_order("# outermost first\nstats\nrates\n"), vec!["stats", "rates"]);
    }
}
