//! The five lint rule families and the tree walk that applies them.
//!
//! Every line-scoped rule is suppressible with an explicit
//! `// lint:allow(<rule>): <reason>` pragma, honored on the offending
//! line's trailing comment or anywhere in the contiguous comment block
//! immediately above it (so a reasoned pragma never has to fight the
//! line-length limit). Tree-scoped rules (the unsafe inventory and the
//! schema cross-check) are governed by the committed manifests
//! instead — see [`super::manifest`].

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use super::lexer::{self, has_word, is_ident, Line};
use super::manifest::{
    in_scope, parse_inventory, parse_lock_order, CI_WORKFLOW, CLOCK_ALLOWLIST, DETERMINISM_SCOPE,
    HARDENED, LENISH, LOCK_ALIASES, LOCK_ORDER_FILE, LOCK_SCOPE, NARROW, SCAN_DIRS, SCHEMA_EMIT,
    UNSAFE_INVENTORY,
};
use super::report::{self, Finding};

/// Result of linting one source file.
pub struct FileScan {
    pub findings: Vec<Finding>,
    /// Lines containing the `unsafe` keyword (the inventory unit).
    pub unsafe_lines: usize,
    /// Identifier-shaped string literals, when `rel` is a schema-emit
    /// file (the supply side of the schema cross-check).
    pub emitted: Vec<String>,
}

/// `comment` carries `lint:allow(<rule>): <nonempty reason>`.
fn pragma(comment: &str, rule: &str) -> bool {
    let key = format!("lint:allow({rule})");
    match comment.find(&key) {
        Some(pos) => {
            let rest = comment[pos + key.len()..].trim_start();
            rest.starts_with(':') && !rest[1..].trim().is_empty()
        }
        None => false,
    }
}

/// A comment line with no code on it (doc or plain) — the unit of the
/// walk-up that attaches a pragma/SAFETY block to the code line below.
fn comment_only(line: &Line) -> bool {
    line.code.trim().is_empty() && !line.comment.is_empty()
}

/// The finding at `lines[idx]` is suppressed: pragma on the line
/// itself, or in the contiguous comment-only block directly above.
fn allowed(lines: &[Line], idx: usize, rule: &str) -> bool {
    if pragma(&lines[idx].comment, rule) {
        return true;
    }
    let mut j = idx;
    while j > 0 && comment_only(&lines[j - 1]) {
        if pragma(&lines[j - 1].comment, rule) {
            return true;
        }
        j -= 1;
    }
    false
}

/// `SAFETY:` on the line's own comment or the contiguous comment block
/// directly above it.
fn has_safety(lines: &[Line], idx: usize) -> bool {
    if lines[idx].comment.contains("SAFETY:") {
        return true;
    }
    let mut j = idx;
    while j > 0 && comment_only(&lines[j - 1]) {
        if lines[j - 1].comment.contains("SAFETY:") {
            return true;
        }
        j -= 1;
    }
    false
}

/// Line ranges (0-based, inclusive) inside `mod tests { ... }` blocks,
/// tracked by brace depth — the parser-hardening rule does not apply
/// to test fixtures.
fn test_mod_ranges(lines: &[Line]) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    let mut depth: i64 = 0;
    let mut start: Option<(usize, i64)> = None;
    for (idx, line) in lines.iter().enumerate() {
        if start.is_none() && line.code.contains("mod tests") && line.code.contains('{') {
            start = Some((idx, depth));
        }
        for c in line.code.chars() {
            if c == '{' {
                depth += 1;
            } else if c == '}' {
                depth -= 1;
                if let Some((s, d)) = start {
                    if depth == d {
                        ranges.push((s, idx));
                        start = None;
                    }
                }
            }
        }
    }
    if let Some((s, _)) = start {
        ranges.push((s, lines.len().saturating_sub(1)));
    }
    ranges
}

fn in_ranges(idx: usize, ranges: &[(usize, usize)]) -> bool {
    ranges.iter().any(|&(a, b)| a <= idx && idx <= b)
}

/// First bare narrowing cast on the line, if any.
fn narrowing_cast(code: &str) -> Option<&'static str> {
    for (i, _) in code.match_indices(" as ") {
        let tok: String = code[i + 4..].chars().take_while(|&c| is_ident(c)).collect();
        if let Some(t) = NARROW.iter().copied().find(|t| *t == tok) {
            return Some(t);
        }
    }
    None
}

/// Token (identifier chars plus `.()`) ending at byte `i`.
fn tok_back(code: &str, i: usize) -> String {
    let cs: Vec<char> = code[..i].chars().collect();
    let mut j = cs.len();
    while j > 0 && (is_ident(cs[j - 1]) || ".()".contains(cs[j - 1])) {
        j -= 1;
    }
    cs[j..].iter().collect()
}

/// Token starting at byte `i`.
fn tok_fwd(code: &str, i: usize) -> String {
    code[i..].chars().take_while(|&c| is_ident(c) || ".()".contains(c)).collect()
}

/// A ` + `/` * ` whose adjacent operand names a length/offset, on a
/// line with none of the checked/capacity/assert escape hatches.
fn lenish_arith(code: &str) -> bool {
    let t = code.trim_start();
    if t.starts_with("assert") || t.starts_with("debug_assert") {
        return false;
    }
    if code.contains("checked_")
        || code.contains("saturating_")
        || code.contains("wrapping_")
        || code.contains("with_capacity")
    {
        return false;
    }
    for op in [" + ", " * "] {
        for (i, _) in code.match_indices(op) {
            let b = tok_back(code, i).to_lowercase();
            let a = tok_fwd(code, i + 3).to_lowercase();
            if LENISH.iter().any(|l| b.contains(l) || a.contains(l)) {
                return true;
            }
        }
    }
    false
}

/// `s` looks like an event/bench schema key: lowercase identifier of
/// at least two characters.
fn is_schema_key(s: &str) -> bool {
    s.len() >= 2
        && !s.starts_with(|c: char| c.is_ascii_digit())
        && s.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

/// Lint one file's source. `ranks` is the parsed lock hierarchy
/// (outermost first); pass the committed manifest's contents in
/// production, or a fixture order in tests.
pub fn lint_source(rel: &str, src: &str, ranks: &[String]) -> FileScan {
    let lines = lexer::lex(src);
    let tranges = test_mod_ranges(&lines);
    let det = in_scope(rel, DETERMINISM_SCOPE);
    let clock_ok = in_scope(rel, CLOCK_ALLOWLIST);
    let hard = HARDENED.contains(&rel);
    let mut findings = Vec::new();
    let mut unsafe_lines = 0usize;
    let mut emitted = Vec::new();

    for (idx, line) in lines.iter().enumerate() {
        let code = &line.code;
        if det && !allowed(&lines, idx, "determinism") {
            if !clock_ok && (code.contains("Instant::now") || code.contains("SystemTime::now")) {
                findings.push(Finding::new(
                    rel,
                    line.no,
                    "determinism",
                    "wall-clock read in a deterministic module",
                ));
            }
            if has_word(code, "HashMap") || has_word(code, "HashSet") {
                findings.push(Finding::new(
                    rel,
                    line.no,
                    "determinism",
                    "hash-ordered collection in a deterministic module (use BTreeMap or sort)",
                ));
            }
        }
        if has_word(code, "unsafe") {
            unsafe_lines += 1;
            if !allowed(&lines, idx, "unsafe") && !has_safety(&lines, idx) {
                findings.push(Finding::new(
                    rel,
                    line.no,
                    "unsafe",
                    "unsafe without a SAFETY: comment",
                ));
            }
        }
        if hard && !in_ranges(idx, &tranges) && !allowed(&lines, idx, "parser") {
            if let Some(t) = narrowing_cast(code) {
                findings.push(Finding::new(
                    rel,
                    line.no,
                    "parser",
                    format!("bare narrowing cast `as {t}` (use try_from/try_into)"),
                ));
            }
            if lenish_arith(code) {
                findings.push(Finding::new(
                    rel,
                    line.no,
                    "parser",
                    "unchecked `+`/`*` on a length/offset (use checked_*)",
                ));
            }
        }
        if SCHEMA_EMIT.contains(&rel) {
            for l in &line.literals {
                if is_schema_key(l) {
                    emitted.push(l.clone());
                }
            }
        }
    }
    if LOCK_SCOPE.contains(&rel) {
        scan_locks(rel, &lines, &tranges, ranks, &mut findings);
    }
    FileScan { findings, unsafe_lines, emitted }
}

/// Same-function nested-acquisition order check against the declared
/// hierarchy. `let`-bound guards are held until their scope closes (or
/// an explicit `drop(var)`); bare acquisitions are transient — checked
/// against what is held, but never themselves held. Cross-function
/// nesting is out of reach for a line scanner; the hierarchy doc in
/// `runtime::pool` covers that half of the contract.
fn scan_locks(
    rel: &str,
    lines: &[Line],
    tranges: &[(usize, usize)],
    ranks: &[String],
    findings: &mut Vec<Finding>,
) {
    let rank_of = |name: &str| ranks.iter().position(|r| r == name);
    let mut depth: i64 = 0;
    // (rank, name, binding depth, binding var)
    let mut held: Vec<(usize, String, i64, String)> = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        let code = &line.code;
        if in_ranges(idx, tranges) {
            for c in code.chars() {
                if c == '{' {
                    depth += 1;
                } else if c == '}' {
                    depth -= 1;
                }
            }
            continue;
        }
        let is_acq = code.contains(".lock()") || code.contains("relock(");
        if is_acq && !allowed(lines, idx, "lock-order") {
            let name = LOCK_ALIASES.iter().find(|(a, _)| code.contains(a)).map(|(_, n)| *n);
            if let Some(rank) = name.and_then(rank_of) {
                let name = name.expect("ranked implies named");
                for (hr, hn, _, _) in &held {
                    if *hr >= rank {
                        findings.push(Finding::new(
                            rel,
                            line.no,
                            "lock-order",
                            format!(
                                "acquire `{name}` while holding `{hn}` (hierarchy: {})",
                                ranks.join(" < ")
                            ),
                        ));
                    }
                }
                let t = code.trim_start();
                if let Some(rest) = t.strip_prefix("let ") {
                    let rest = rest.strip_prefix("mut ").unwrap_or(rest);
                    let var: String = rest.chars().take_while(|&c| is_ident(c)).collect();
                    held.push((rank, name.to_string(), depth, var));
                }
            }
        } else if code.contains("ledger::") && !allowed(lines, idx, "lock-order") {
            // ledger:: helpers lock internally — a transient
            // acquisition even without `.lock()` on the line.
            if let Some(rank) = rank_of("ledger") {
                for (hr, hn, _, _) in &held {
                    if *hr >= rank {
                        findings.push(Finding::new(
                            rel,
                            line.no,
                            "lock-order",
                            format!("acquire `ledger` (via ledger:: helper) while holding `{hn}`"),
                        ));
                    }
                }
            }
        }
        if let Some(pos) = code.find("drop(") {
            let arg: String = code[pos + 5..].chars().take_while(|&c| c != ')').collect();
            let arg = arg.trim().trim_start_matches('&').to_string();
            held.retain(|(_, _, _, v)| *v != arg);
        }
        for c in code.chars() {
            if c == '{' {
                depth += 1;
            } else if c == '}' {
                depth -= 1;
                held.retain(|(_, _, bd, _)| *bd <= depth);
            }
        }
    }
}

/// Quoted `'...'`/`"..."` spans in a CI line: (start byte of the open
/// quote, byte just past the close quote, contents).
fn quoted(line: &str) -> Vec<(usize, usize, String)> {
    let cs: Vec<(usize, char)> = line.char_indices().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < cs.len() {
        let (bi, c) = cs[i];
        if c == '\'' || c == '"' {
            let mut j = i + 1;
            while j < cs.len() && cs[j].1 != c {
                j += 1;
            }
            if j < cs.len() {
                let content: String = cs[i + 1..j].iter().map(|&(_, ch)| ch).collect();
                out.push((bi, cs[j].0 + c.len_utf8(), content));
                i = j + 1;
                continue;
            }
        }
        i += 1;
    }
    out
}

/// If the line contains `for <ident> in (`, return the text after the
/// opening parenthesis.
fn for_tuple_rest(line: &str) -> Option<String> {
    let cs: Vec<char> = line.chars().collect();
    let n = cs.len();
    for i in 0..n.saturating_sub(2) {
        if cs[i] != 'f' || cs[i + 1] != 'o' || cs[i + 2] != 'r' {
            continue;
        }
        if i > 0 && is_ident(cs[i - 1]) {
            continue;
        }
        let mut j = i + 3;
        let ws = j;
        while j < n && cs[j].is_whitespace() {
            j += 1;
        }
        if j == ws {
            continue;
        }
        let id = j;
        while j < n && is_ident(cs[j]) {
            j += 1;
        }
        if j == id {
            continue;
        }
        let ws2 = j;
        while j < n && cs[j].is_whitespace() {
            j += 1;
        }
        if j == ws2 || j + 1 >= n || cs[j] != 'i' || cs[j + 1] != 'n' {
            continue;
        }
        j += 2;
        let ws3 = j;
        while j < n && cs[j].is_whitespace() {
            j += 1;
        }
        if j == ws3 || j >= n || cs[j] != '(' {
            continue;
        }
        return Some(cs[j + 1..].iter().collect());
    }
    None
}

/// Field names the CI python asserts consume, extracted from the
/// `python3 - <<'EOF'` heredocs in the workflow file. Four contexts
/// count as a consuming read: `.get('k')`, `['k']`, `'k' in x`, and
/// quoted names inside a (possibly multi-line) `for v in (...)` tuple.
pub fn extract_ci_keys(yml: &str) -> BTreeSet<String> {
    let mut keys = BTreeSet::new();
    let mut heredoc = false;
    let mut open_tuple = false;
    for raw in yml.lines() {
        if !heredoc {
            if raw.contains("<<'EOF'") || raw.contains("<<\"EOF\"") || raw.contains("<<EOF") {
                heredoc = true;
                open_tuple = false;
            }
            continue;
        }
        if raw.trim() == "EOF" {
            heredoc = false;
            continue;
        }
        for (start, end, content) in quoted(raw) {
            if !is_schema_key(&content) {
                continue;
            }
            let before = &raw[..start];
            let after = &raw[end..];
            let get_ctx = before.trim_end().ends_with(".get(");
            let bracket_ctx = before.ends_with('[') && after.starts_with(']');
            let in_ctx = after.starts_with(|c: char| c.is_whitespace()) && {
                let a = after.trim_start();
                a.strip_prefix("in").is_some_and(|r| {
                    r.starts_with(|c: char| c.is_whitespace())
                        && r.trim_start().starts_with(|c: char| c.is_ascii_alphabetic() || c == '_')
                })
            };
            if get_ctx || bracket_ctx || in_ctx {
                keys.insert(content);
            }
        }
        if open_tuple {
            for (_, _, content) in quoted(raw) {
                if is_schema_key(&content) {
                    keys.insert(content);
                }
            }
            if raw.contains(')') {
                open_tuple = false;
            }
        }
        if let Some(rest) = for_tuple_rest(raw) {
            for (_, _, content) in quoted(&rest) {
                if is_schema_key(&content) {
                    keys.insert(content);
                }
            }
            if !rest.contains(')') {
                open_tuple = true;
            }
        }
    }
    keys
}

/// CI-asserted keys no schema-emit file carries.
pub fn schema_missing(ci: &BTreeSet<String>, emitted: &BTreeSet<String>) -> Vec<String> {
    ci.difference(emitted).cloned().collect()
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    if !dir.exists() {
        return Ok(());
    }
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint the whole tree rooted at the repo root: every `.rs` file under
/// [`SCAN_DIRS`], plus the manifest and schema cross-checks. Findings
/// come back in stable report order; empty means clean.
pub fn lint_tree(root: &Path) -> std::io::Result<Vec<Finding>> {
    let mut findings = Vec::new();

    let ranks: Vec<String> = match std::fs::read_to_string(root.join(LOCK_ORDER_FILE)) {
        Ok(text) => parse_lock_order(&text),
        Err(_) => {
            findings.push(Finding::new(
                LOCK_ORDER_FILE,
                0,
                "lock-order",
                "missing lock hierarchy manifest",
            ));
            Vec::new()
        }
    };
    if !ranks.is_empty() {
        for (_, name) in LOCK_ALIASES {
            if !ranks.iter().any(|r| r == name) {
                findings.push(Finding::new(
                    LOCK_ORDER_FILE,
                    0,
                    "lock-order",
                    format!("lock `{name}` is not ranked in the hierarchy manifest"),
                ));
            }
        }
    }

    let mut files: Vec<PathBuf> = Vec::new();
    for d in SCAN_DIRS {
        walk(&root.join(d), &mut files)?;
    }
    files.sort();

    let mut unsafe_counts: Vec<(String, usize)> = Vec::new();
    let mut emitted: BTreeSet<String> = BTreeSet::new();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(path)?;
        let scan = lint_source(&rel, &src, &ranks);
        findings.extend(scan.findings);
        if scan.unsafe_lines > 0 {
            unsafe_counts.push((rel.clone(), scan.unsafe_lines));
        }
        emitted.extend(scan.emitted);
    }

    match std::fs::read_to_string(root.join(UNSAFE_INVENTORY)) {
        Ok(text) => {
            let inv = parse_inventory(&text);
            for (file, n) in &unsafe_counts {
                match inv.iter().find(|(f, _)| f == file) {
                    Some((_, m)) if m == n => {}
                    Some((_, m)) => findings.push(Finding::new(
                        file,
                        0,
                        "unsafe",
                        format!(
                            "{n} unsafe line(s) but {UNSAFE_INVENTORY} says {m} — re-audit and update it"
                        ),
                    )),
                    None => findings.push(Finding::new(
                        file,
                        0,
                        "unsafe",
                        format!("{n} unsafe line(s) not enumerated in {UNSAFE_INVENTORY}"),
                    )),
                }
            }
            for (file, _) in &inv {
                if !unsafe_counts.iter().any(|(f, _)| f == file) {
                    findings.push(Finding::new(
                        UNSAFE_INVENTORY,
                        0,
                        "unsafe",
                        format!("stale entry: `{file}` has no unsafe lines (or no longer exists)"),
                    ));
                }
            }
        }
        Err(_) => {
            if !unsafe_counts.is_empty() {
                findings.push(Finding::new(
                    UNSAFE_INVENTORY,
                    0,
                    "unsafe",
                    "missing unsafe inventory (files in the tree contain unsafe)",
                ));
            }
        }
    }

    if let Ok(yml) = std::fs::read_to_string(root.join(CI_WORKFLOW)) {
        for key in schema_missing(&extract_ci_keys(&yml), &emitted) {
            findings.push(Finding::new(
                CI_WORKFLOW,
                0,
                "schema",
                format!("CI asserts `{key}` but no schema-emit file carries it"),
            ));
        }
    }

    report::sort(&mut findings);
    Ok(findings)
}

/// Unsafe-line counts per file for the current tree — what the
/// committed inventory must match exactly.
pub fn unsafe_census(root: &Path) -> std::io::Result<Vec<(String, usize)>> {
    let mut files: Vec<PathBuf> = Vec::new();
    for d in SCAN_DIRS {
        walk(&root.join(d), &mut files)?;
    }
    files.sort();
    let mut out = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(path)?;
        let scan = lint_source(&rel, &src, &[]);
        if scan.unsafe_lines > 0 {
            out.push((rel, scan.unsafe_lines));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ranks() -> Vec<String> {
        ["stats", "rates", "ledger", "health", "cache"].iter().map(|s| s.to_string()).collect()
    }

    fn findings(rel: &str, src: &str) -> Vec<Finding> {
        lint_source(rel, src, &ranks()).findings
    }

    #[test]
    fn determinism_flags_clock_and_hash_collections() {
        let src = "let t = Instant::now();\nlet m: HashMap<u32, f32> = HashMap::new();\n";
        let f = findings("rust/src/selection/fixture.rs", src);
        assert_eq!(f.len(), 2, "{}", report::render(&f));
        assert!(f.iter().all(|x| x.rule == "determinism"));
    }

    #[test]
    fn determinism_ignores_out_of_scope_strings_and_pragmas() {
        let clock = "let t = Instant::now();\n";
        assert!(findings("rust/src/util/math.rs", clock).is_empty(), "out of scope");
        let in_string = "let m = \"uses a HashMap and Instant::now\";\n";
        assert!(findings("rust/src/selection/fixture.rs", in_string).is_empty(), "literal only");
        let sup = "// lint:allow(determinism): fixture needs a clock\nlet t = Instant::now();\n";
        assert!(findings("rust/src/selection/fixture.rs", sup).is_empty(), "pragma");
    }

    #[test]
    fn pragma_requires_a_reason() {
        let bare = "let t = Instant::now(); // lint:allow(determinism)\n";
        assert_eq!(findings("rust/src/selection/fixture.rs", bare).len(), 1);
        let reasoned = "let t = Instant::now(); // lint:allow(determinism): fixture clock\n";
        assert!(findings("rust/src/selection/fixture.rs", reasoned).is_empty());
    }

    #[test]
    fn unsafe_requires_safety_comment() {
        let bad = "unsafe { do_it() }\n";
        let f = findings("rust/src/util/fixture.rs", bad);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "unsafe");
        let trailing = "unsafe { do_it() } // SAFETY: bounds checked above.\n";
        assert!(findings("rust/src/util/fixture.rs", trailing).is_empty());
        let multi = "// SAFETY: the pointer is valid because the region\n// outlives self and is never written.\nunsafe { do_it() }\n";
        assert!(findings("rust/src/util/fixture.rs", multi).is_empty());
        let pragma = "// lint:allow(unsafe): audited fixture\nunsafe { do_it() }\n";
        assert!(findings("rust/src/util/fixture.rs", pragma).is_empty());
    }

    #[test]
    fn unsafe_lines_are_counted_for_the_inventory() {
        let src = "// SAFETY: a.\nunsafe { a() }\nfn not_unsafe() {}\n// SAFETY: b.\nlet x = unsafe { b() };\n";
        assert_eq!(lint_source("rust/src/util/fixture.rs", src, &ranks()).unsafe_lines, 2);
    }

    #[test]
    fn parser_rules_flag_narrowing_and_unchecked_arith() {
        let src = "let d = n as u32;\nlet end = base + rec.len * 4;\n";
        let f = findings("rust/src/data/store/format.rs", src);
        assert_eq!(f.len(), 2, "{}", report::render(&f));
        assert!(f.iter().all(|x| x.rule == "parser"));
        // same lines outside the hardened parser scope are fine
        assert!(findings("rust/src/util/fixture.rs", src).is_empty());
    }

    #[test]
    fn parser_rules_accept_checked_forms_asserts_and_pragmas() {
        let src = "let d = u32::try_from(n).expect(\"fits\");\n\
                   let end = base.checked_add(rec_len).unwrap();\n\
                   let v = Vec::with_capacity(rows * 4);\n\
                   assert_eq!(xs.len(), rows * d, \"xs length\");\n\
                   let wide = rows as u64;\n";
        assert!(findings("rust/src/data/store/format.rs", src).is_empty());
        let sup = "// lint:allow(parser): proven in-bounds at open.\nlet end = base + rec.len * 4;\n";
        assert!(findings("rust/src/data/store/format.rs", sup).is_empty());
    }

    #[test]
    fn parser_rules_skip_test_modules() {
        let src = "mod tests {\n    fn f() { let d = n as u32; }\n}\n";
        assert!(findings("rust/src/data/store/format.rs", src).is_empty());
    }

    #[test]
    fn lock_order_flags_inverted_acquisition() {
        let src = "fn bad(&self) {\n    let h = self.health.lock().unwrap();\n    let st = self.stats.lock().unwrap();\n}\n";
        let f = findings("rust/src/runtime/pool.rs", src);
        assert_eq!(f.len(), 1, "{}", report::render(&f));
        assert_eq!(f[0].rule, "lock-order");
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn lock_order_accepts_hierarchy_and_released_guards() {
        let good = "fn report(&self) {\n    let st = self.stats.lock().unwrap();\n    let r = self.rates.lock().unwrap();\n    ledger::snapshot(self.id);\n}\n";
        assert!(findings("rust/src/runtime/pool.rs", good).is_empty(), "in-order");
        let dropped = "fn seq(&self) {\n    let h = self.health.lock().unwrap();\n    drop(h);\n    let st = self.stats.lock().unwrap();\n}\n";
        assert!(findings("rust/src/runtime/pool.rs", dropped).is_empty(), "drop releases");
        let scoped = "fn scoped(&self) {\n    {\n        let h = self.health.lock().unwrap();\n    }\n    let st = self.stats.lock().unwrap();\n}\n";
        assert!(findings("rust/src/runtime/pool.rs", scoped).is_empty(), "scope releases");
    }

    #[test]
    fn lock_order_pragma_suppresses() {
        let src = "fn odd(&self) {\n    let h = self.health.lock().unwrap();\n    // lint:allow(lock-order): disjoint per-slot mutex here.\n    let st = self.stats.lock().unwrap();\n}\n";
        assert!(findings("rust/src/runtime/pool.rs", src).is_empty());
    }

    #[test]
    fn schema_extracts_ci_keys_from_heredocs_only() {
        let yml = "      run: |\n          python3 - <<'EOF'\n          ev = json.loads(line)\n          assert ev.get('loss') is not None\n          assert ev['step'] >= 0\n          assert 'cache_hits' in ev\n          for k in ('hits', 'misses',\n                    'evictions'):\n              assert k in stats\n          EOF\n      - name: outside\n        run: python3 -c \"x['not_a_key']\"\n";
        let keys = extract_ci_keys(yml);
        let got: Vec<&str> = keys.iter().map(String::as_str).collect();
        assert_eq!(got, vec!["cache_hits", "evictions", "hits", "loss", "misses", "step"]);
    }

    #[test]
    fn schema_missing_keys_are_reported() {
        let emitted: BTreeSet<String> = ["loss", "step"].iter().map(|s| s.to_string()).collect();
        let ci: BTreeSet<String> =
            ["ghost", "loss", "step"].iter().map(|s| s.to_string()).collect();
        assert_eq!(schema_missing(&ci, &emitted), vec!["ghost".to_string()]);
        assert!(schema_missing(&emitted, &emitted).is_empty());
    }

    #[test]
    fn schema_emit_files_collect_identifier_literals() {
        let src = "emit(\"train_step\", vec![(\"loss\", num(l))]);\nlet msg = \"Not A Key\";\n";
        let e = lint_source("rust/src/coordinator/events.rs", src, &ranks()).emitted;
        assert!(e.contains(&"train_step".to_string()) && e.contains(&"loss".to_string()));
        assert!(!e.iter().any(|k| k.contains(' ')), "{e:?}");
        // non-emit files contribute nothing
        let other = lint_source("rust/src/util/fixture.rs", src, &ranks()).emitted;
        assert!(other.is_empty());
    }
}
