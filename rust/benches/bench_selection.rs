//! Selection-path microbenchmarks (custom harness; criterion is not in
//! the vendored crate set).
//!
//! Covers the L3 hot path end to end: top-k ranking, candidate gather,
//! fused-Pallas RHO scoring vs fwd-stats scoring, and scoring-pool
//! scaling across workers. Prints mean / p50 / p95 latency per op.

use std::rc::Rc;
use std::time::Instant;

use rho::data::synth::{Generator, SynthSpec};
use rho::runtime::artifact::{default_dir, Manifest};
use rho::runtime::handle::{cpu_client, ModelRuntime};
use rho::runtime::pool::{PoolConfig, ScoringPool};
use rho::util::math::top_k_indices;
use rho::util::rng::Pcg32;
use rho::util::timer::LatencyHist;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // warmup
    for _ in 0..3.min(iters) {
        f();
    }
    let mut h = LatencyHist::new();
    for _ in 0..iters {
        let t = Instant::now();
        f();
        h.record(t.elapsed());
    }
    println!("{name:<44} {}", h.summary());
}

fn main() {
    println!("== bench_selection ==");
    let mut rng = Pcg32::new(42, 0);

    // ---- pure-Rust selection primitives -----------------------------
    let scores: Vec<f32> = (0..320).map(|_| rng.gauss()).collect();
    bench("top_k(320 -> 32)", 2000, || {
        std::hint::black_box(top_k_indices(&scores, 32));
    });
    let scores_big: Vec<f32> = (0..100_000).map(|_| rng.gauss()).collect();
    bench("top_k(100k -> 32)", 200, || {
        std::hint::black_box(top_k_indices(&scores_big, 32));
    });

    let gen = Generator::new(SynthSpec::image(256, 10, 1.0), 1);
    let ds = gen.sample(20_000, &mut rng);
    let idx: Vec<u32> = (0..320u32).map(|i| i * 7 % 20_000).collect();
    let (mut gx, mut gy) = (Vec::new(), Vec::new());
    bench("gather 320x256 candidate batch", 2000, || {
        ds.gather_into(&idx, &mut gx, &mut gy);
    });

    // ---- HLO-backed scoring ------------------------------------------
    let dir = default_dir();
    if !dir.join("manifest.json").exists() {
        println!("(artifacts missing: skipping runtime benches — run `make artifacts`)");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let client = cpu_client().unwrap();
    for arch in ["mlp_small", "mlp_base", "cnn_small"] {
        let (d, c) = (256usize, 10usize);
        let rt = match ModelRuntime::load(Rc::clone(&client), &manifest, arch, d, c) {
            Ok(rt) => rt,
            Err(_) => continue,
        };
        let st = rt.init(1).unwrap();
        let idx: Vec<u32> = (0..320u32).collect();
        let (xs, ys) = ds.gather(&idx);
        let il = vec![0.5f32; 320];
        bench(&format!("{arch}: fwd stats 320 (4 signals)"), 60, || {
            std::hint::black_box(rt.fwd(&st.theta, &xs, &ys).unwrap());
        });
        bench(&format!("{arch}: fused rho select 320"), 60, || {
            std::hint::black_box(rt.select_rho(&st.theta, &xs, &ys, &il).unwrap());
        });
        let w = vec![1.0f32; 32];
        let (txs, tys) = ds.gather(&idx[..32]);
        let mut stt = rt.init(2).unwrap();
        bench(&format!("{arch}: train step (32)"), 60, || {
            rt.train_step(&mut stt, &txs, &tys, &w, 1e-3, 1e-2).unwrap();
        });
    }

    // ---- scoring-pool scaling ----------------------------------------
    let fwd_meta = manifest.find("mlp_base", 256, 10, "fwd_b320").unwrap();
    let sel_meta = manifest.find("mlp_base", 256, 10, "select_b320").unwrap();
    let rt = ModelRuntime::load(Rc::clone(&client), &manifest, "mlp_base", 256, 10).unwrap();
    let theta = rt.init(3).unwrap().theta_snapshot();
    let big: Vec<u32> = (0..3200u32).map(|i| i % 20_000).collect();
    let (bxs, bys) = ds.gather(&big);
    // zero-copy dispatch: the batch and il cross into the pool as Arc
    // refcount bumps, one gather for the whole sweep
    let batch = rho::runtime::pool::CandBatch::for_scoring(bxs, bys);
    let bil = std::sync::Arc::new(vec![0.5f32; 3200]);
    let mut base_mean = 0.0f32;
    for workers in [1usize, 2, 4] {
        let pool = ScoringPool::new(
            fwd_meta,
            sel_meta,
            None,
            &PoolConfig { workers, lane_depth: 16, ..PoolConfig::default() },
        )
        .unwrap();
        let mut h = LatencyHist::new();
        for _ in 0..20 {
            let t = Instant::now();
            std::hint::black_box(pool.rho(&theta, &batch, &bil).unwrap());
            h.record(t.elapsed());
        }
        if workers == 1 {
            base_mean = h.mean_us();
        }
        let t = rho::coordinator::metrics::DispatchTimings::from_report("target", &pool.report());
        println!(
            "pool rho 3200 pts, workers={workers:<2}              {} (speedup {:.2}x, queue-wait {:.0}us/chunk)",
            h.summary(),
            base_mean / h.mean_us(),
            t.mean_queue_wait_us
        );
    }
}
