//! End-to-end engine throughput (steps/sec): the unified streaming
//! engine across selection methods (uniform / train_loss / rho_loss),
//! target-plane sizes (workers ∈ {1, 4}), and data sources
//! (`memory` vs `shards` — the mmap ShardStore data plane), against
//! each method's inline reference. This regenerates the paper's §3
//! parallelized-selection claim at bench scale — for every method,
//! not just fused RHO — and is the primary L3 perf target
//! (EXPERIMENTS.md §Perf).
//!
//! Besides the human-readable table, every run (over)writes its
//! measured numbers to `BENCH_pipeline.json` (one entry per method ×
//! workers × source, plus per-plane dispatch/queue-wait timings and
//! the shard-ingest bytes/sec); committing the file per PR makes the
//! perf trajectory machine-trackable.
//!
//! `RHO_BENCH_SMOKE=1` switches to smoke mode (tiny dataset scale, 1
//! epoch — a handful of steps per method, one worker) so CI can prove
//! the harness end-to-end and upload the JSON without paying bench
//! wall-clock; when artifacts are missing the JSON still lands with
//! `"skipped": true`.

use std::rc::Rc;

use rho::config::RunConfig;
use rho::coordinator::{IlContext, Session};
use rho::experiments::common::Lab;
use rho::experiments::ExpCtx;
use rho::runtime::plane::ComputePlane;
use rho::runtime::pool::{PoolConfig, ScoringPool};
use rho::selection::Method;
use rho::util::json::{arr, num, obj, s, Value};

fn write_doc(doc: Value) {
    let path = std::path::Path::new("BENCH_pipeline.json");
    match std::fs::write(path, doc.to_json() + "\n") {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}

fn main() {
    let smoke = std::env::var("RHO_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    println!("== bench_pipeline{} ==", if smoke { " (smoke)" } else { "" });
    let ctx = ExpCtx::new(if smoke { 0.05 } else { 0.25 });
    if !ctx.artifacts.join("manifest.json").exists() {
        println!("(artifacts missing: run `make artifacts`)");
        write_doc(obj(vec![
            ("bench", s("pipeline")),
            ("skipped", Value::Bool(true)),
            ("reason", s("artifact manifest missing")),
        ]));
        return;
    }
    let lab = Lab::new(&ctx).unwrap();
    let base = RunConfig {
        dataset: "cifar10".into(),
        arch: "mlp_base".into(),
        il_arch: "mlp_small".into(),
        method: Method::RhoLoss,
        epochs: if smoke { 1 } else { 3 },
        il_epochs: if smoke { 1 } else { 4 },
        ..Default::default()
    };
    let worker_sweep: &[usize] = if smoke { &[1] } else { &[1, 4] };
    let bundle = lab.bundle(&base.dataset);
    let target = lab.runtime(&base.arch, &base.dataset).unwrap();
    let (d, c) = rho::data::catalog::dims_for(&base.dataset);
    let fwd = lab.manifest.find(&base.arch, d, c, "fwd_b320").unwrap();
    let sel = lab.manifest.find(&base.arch, d, c, "select_b320").unwrap();

    let mut sync_by_method = std::collections::HashMap::new();
    let mut entries: Vec<Value> = Vec::new();
    for method in [Method::Uniform, Method::TrainLoss, Method::RhoLoss] {
        let mut cfg = base.clone();
        cfg.method = method;
        let il: Option<std::rc::Rc<IlContext>> = if method.needs_il() {
            Some(lab.il_context(&cfg, &bundle).unwrap())
        } else {
            None
        };
        let il_ref = il.as_deref();

        let sync = Session::new(&cfg, &target).run(&bundle, il_ref).unwrap();
        let sync_sps = sync.steps_per_sec();
        sync_by_method.insert(method, sync_sps);
        println!("{:<12} inline:             {sync_sps:>7.1} steps/s", method.name());
        entries.push(obj(vec![
            ("method", s(method.name())),
            ("source", s("memory")),
            ("workers", num(0.0)), // 0 = inline reference
            ("steps_per_sec", num(sync_sps)),
        ]));

        for &workers in worker_sweep {
            let pool = ScoringPool::new(
                fwd,
                sel,
                None,
                &PoolConfig { workers, lane_depth: 16, ..PoolConfig::default() },
            )
            .unwrap();
            let plane = ComputePlane::new("target", base.arch.clone(), Rc::new(pool));
            let res = Session::new(&cfg, &target)
                .plane(&plane)
                .prefetch(4)
                .run(&bundle, il_ref)
                .unwrap();
            let sps = res.steps_per_sec();
            let t = res.plane_timings.first().cloned().unwrap_or_default();
            println!(
                "{:<12} plane workers={workers}:   {sps:>7.1} steps/s ({:+.0}% vs inline, queue-wait {:.0}us/chunk)",
                method.name(),
                (sps / sync_sps - 1.0) * 100.0,
                t.mean_queue_wait_us
            );
            entries.push(obj(vec![
                ("method", s(method.name())),
                ("source", s("memory")),
                ("workers", num(workers as f64)),
                ("steps_per_sec", num(sps)),
                ("vs_sync_pct", num((sps / sync_sps - 1.0) * 100.0)),
                ("plane", s(&t.plane)),
                ("dispatches", num(t.dispatches as f64)),
                ("chunks", num(t.chunks as f64)),
                ("mean_queue_wait_us", num(t.mean_queue_wait_us)),
                ("mean_busy_us", num(t.mean_busy_us)),
                ("worker_chunks", arr(t.worker_chunks.iter().map(|&ch| num(ch as f64)))),
                ("worker_rates", arr(t.worker_rates.iter().map(|&r| num(r)))),
            ]));
        }
    }

    // --- source=shards axis: the on-disk data plane ------------------
    // Ingest the bundle once (measuring bytes/sec), write IL sidecars
    // straight from the amortized IL table, then stream the same runs
    // from the mmap'd store. At workers=1 the curves are bitwise the
    // memory curves (tests/store_integration.rs); here we record what
    // the substrate swap costs in steps/sec.
    let store_dir =
        std::env::temp_dir().join(format!("rho-bench-store-{}", std::process::id()));
    std::fs::remove_dir_all(&store_dir).ok();
    let ingest_sw = rho::util::timer::Stopwatch::start();
    let report = rho::data::store::ingest_bundle(&bundle, &store_dir, 1024).unwrap();
    let ingest_secs = ingest_sw.elapsed_s();
    let ingest_bps = if ingest_secs > 0.0 { report.total_bytes() as f64 / ingest_secs } else { 0.0 };
    println!(
        "ingest: {} rows, {:.1} MiB at {:.0} MiB/s -> {}",
        report.total_rows(),
        report.total_bytes() as f64 / (1024.0 * 1024.0),
        ingest_bps / (1024.0 * 1024.0),
        store_dir.display()
    );
    {
        // sidecars from the already-computed IL table (score-il's output
        // bytes, without re-measuring IL training here)
        let mut rho_cfg = base.clone();
        rho_cfg.method = Method::RhoLoss;
        let il = lab.il_context(&rho_cfg, &bundle).unwrap();
        let store = rho::data::store::ShardStore::open(&store_dir).unwrap();
        let mut off = 0usize;
        for shard in store.train.shards() {
            rho::data::store::write_sidecar(&shard.path, &il.values[off..off + shard.rows])
                .unwrap();
            off += shard.rows;
        }
    }
    let shard_workers: Vec<usize> = if smoke { vec![0] } else { vec![0, 4] };
    for method in [Method::Uniform, Method::RhoLoss] {
        for &workers in &shard_workers {
            let mut cfg = base.clone();
            cfg.method = method;
            cfg.workers = workers;
            cfg.source = format!("shards://{}", store_dir.display());
            let res = lab.run_auto(&cfg).unwrap();
            let sps = res.steps_per_sec();
            let vs = sync_by_method.get(&method).copied().unwrap_or(0.0);
            println!(
                "{:<12} shards workers={workers}:  {sps:>7.1} steps/s ({:+.0}% vs memory inline)",
                method.name(),
                if vs > 0.0 { (sps / vs - 1.0) * 100.0 } else { 0.0 }
            );
            entries.push(obj(vec![
                ("method", s(method.name())),
                ("source", s("shards")),
                ("workers", num(workers as f64)),
                ("steps_per_sec", num(sps)),
            ]));
        }
    }
    std::fs::remove_dir_all(&store_dir).ok();

    // Selection-overhead ratio (paper §3: the selection fwd pass costs
    // n_B/(3 n_b) of a train step in theory), from the inline runs.
    let uni_sps = sync_by_method[&Method::Uniform];
    let rho_sps = sync_by_method[&Method::RhoLoss];
    println!(
        "uniform/rho inline ratio: {:.2}x (paper theory ~{:.2}x fwd-only)",
        uni_sps / rho_sps,
        1.0 + 320.0 / (3.0 * 32.0)
    );

    // Machine-readable perf record (steps/sec per method × workers ×
    // source, plus the shard-ingest throughput).
    write_doc(obj(vec![
        ("bench", s("pipeline")),
        ("smoke", Value::Bool(smoke)),
        ("scale", num(ctx.scale)),
        ("epochs", num(base.epochs as f64)),
        ("uniform_over_rho_sync", num(uni_sps / rho_sps)),
        ("ingest_bytes_per_sec", num(ingest_bps)),
        ("ingest_rows", num(report.total_rows() as f64)),
        ("entries", Value::Array(entries)),
    ]));
}
