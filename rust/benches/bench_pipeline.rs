//! End-to-end engine throughput (steps/sec): the unified streaming
//! engine across selection methods (uniform / train_loss / rho_loss)
//! and pool sizes (workers ∈ {1, 4}), against each method's
//! synchronous inline reference. This regenerates the paper's §3
//! parallelized-selection claim at bench scale — now for every
//! method, not just fused RHO — and is the primary L3 perf target
//! (EXPERIMENTS.md §Perf).

use rho::config::RunConfig;
use rho::coordinator::engine::run_pipelined;
use rho::coordinator::trainer::{IlContext, Trainer};
use rho::experiments::common::Lab;
use rho::experiments::ExpCtx;
use rho::runtime::pool::{PoolConfig, ScoringPool};
use rho::selection::Method;
use rho::util::timer::Stopwatch;

fn main() {
    println!("== bench_pipeline ==");
    let ctx = ExpCtx::new(0.25);
    if !ctx.artifacts.join("manifest.json").exists() {
        println!("(artifacts missing: run `make artifacts`)");
        return;
    }
    let lab = Lab::new(&ctx).unwrap();
    let base = RunConfig {
        dataset: "cifar10".into(),
        arch: "mlp_base".into(),
        il_arch: "mlp_small".into(),
        method: Method::RhoLoss,
        epochs: 3,
        il_epochs: 4,
        ..Default::default()
    };
    let bundle = lab.bundle(&base.dataset);
    let target = lab.runtime(&base.arch, &base.dataset).unwrap();
    let (d, c) = rho::data::catalog::dims_for(&base.dataset);
    let fwd = lab.manifest.find(&base.arch, d, c, "fwd_b320").unwrap();
    let sel = lab.manifest.find(&base.arch, d, c, "select_b320").unwrap();

    let mut sync_by_method = std::collections::HashMap::new();
    for method in [Method::Uniform, Method::TrainLoss, Method::RhoLoss] {
        let mut cfg = base.clone();
        cfg.method = method;
        let il: Option<std::rc::Rc<IlContext>> = if method.needs_il() {
            Some(lab.il_context(&cfg, &bundle).unwrap())
        } else {
            None
        };
        let il_ref = il.as_deref();

        let sw = Stopwatch::start();
        let sync = Trainer::new(&cfg, &target).run(&bundle, il_ref).unwrap();
        let sync_sps = sync.steps as f64 / sw.elapsed_s();
        sync_by_method.insert(method, sync_sps);
        println!("{:<12} sync (inline):      {sync_sps:>7.1} steps/s", method.name());

        for workers in [1usize, 4] {
            let pool =
                ScoringPool::new(fwd, sel, None, &PoolConfig { workers, queue_depth: 16 })
                    .unwrap();
            let (_, sps) = run_pipelined(&cfg, &target, &pool, &bundle, il_ref, 4).unwrap();
            println!(
                "{:<12} pool workers={workers}:    {sps:>7.1} steps/s ({:+.0}% vs sync)",
                method.name(),
                (sps / sync_sps - 1.0) * 100.0
            );
        }
    }

    // Selection-overhead ratio (paper §3: the selection fwd pass costs
    // n_B/(3 n_b) of a train step in theory), from the sync runs above.
    let uni_sps = sync_by_method[&Method::Uniform];
    let rho_sps = sync_by_method[&Method::RhoLoss];
    println!(
        "uniform/rho sync ratio: {:.2}x (paper theory ~{:.2}x fwd-only)",
        uni_sps / rho_sps,
        1.0 + 320.0 / (3.0 * 32.0)
    );
}
