//! End-to-end engine throughput (steps/sec): the unified streaming
//! engine across selection methods (uniform / train_loss / rho_loss),
//! target-plane sizes (workers ∈ {1, 4}), and data sources
//! (`memory` vs `shards` — the mmap ShardStore data plane — vs
//! `remote` — ranged reads over HTTP through the bounded LRU shard
//! cache, served by the in-repo range server), against each method's
//! inline reference. This regenerates the paper's §3
//! parallelized-selection claim at bench scale — for every method,
//! not just fused RHO — and is the primary L3 perf target
//! (EXPERIMENTS.md §Perf).
//!
//! Besides the human-readable table, every run (over)writes its
//! measured numbers to `BENCH_pipeline.json` (one entry per method ×
//! workers × source, plus per-plane dispatch/queue-wait timings,
//! supervision health/recovery counters, remote cache
//! hit/miss/eviction counters, and the shard-ingest
//! bytes/sec); committing the file per PR makes the perf trajectory
//! machine-trackable. The two-plane rho_loss +
//! online_il run is additionally swept over `speculate` ∈ {0, 1} and
//! records `train_overlap_s` — the scoring wall-clock that ran under
//! an open gradient step, i.e. what staleness-1 speculation buys. A
//! `serve` record measures the multi-session scheduler: two weighted
//! tenants time-sliced over one shared pool, with aggregate steps/sec
//! and the DRR fairness imbalance.
//!
//! `RHO_BENCH_SMOKE=1` switches to smoke mode (tiny dataset scale, 1
//! epoch — a handful of steps per method, one worker) so CI can prove
//! the harness end-to-end and upload the JSON without paying bench
//! wall-clock; when artifacts are missing the JSON still lands with
//! `"skipped": true`.

use std::rc::Rc;

use rho::config::RunConfig;
use rho::coordinator::{IlContext, Session};
use rho::experiments::common::Lab;
use rho::experiments::ExpCtx;
use rho::runtime::plane::ComputePlane;
use rho::runtime::pool::{PoolConfig, ScoringPool};
use rho::selection::Method;
use rho::util::json::{arr, num, obj, s, Value};

fn write_doc(doc: Value) {
    let path = std::path::Path::new("BENCH_pipeline.json");
    match std::fs::write(path, doc.to_json() + "\n") {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}

/// The cross-plane overlap record for the two-plane `rho_loss` +
/// `online_il` run: wall seconds each plane had work in flight, wall
/// seconds they overlapped, the per-step overlap headline, and the
/// scoring-over-train overlap `speculate=1` buys. Always present in
/// BENCH_pipeline.json (zeroed when skipped) so tooling can rely on
/// the schema.
fn overlap_doc(
    target_inflight_s: f64,
    il_inflight_s: f64,
    overlap_s: f64,
    per_step_s: f64,
    train_overlap_s: f64,
    steps: u64,
) -> Value {
    obj(vec![
        ("target_inflight_s", num(target_inflight_s)),
        ("il_inflight_s", num(il_inflight_s)),
        ("overlap_s", num(overlap_s)),
        ("per_step_s", num(per_step_s)),
        ("train_overlap_s", num(train_overlap_s)),
        ("steps", num(steps as f64)),
    ])
}

/// The `speculate` sweep axis, recorded top-level so tooling can
/// discover which speculation settings the entries cover.
fn speculate_axis() -> Value {
    arr([num(0.0), num(1.0)])
}

/// Settled remote shard-cache counters for the whole bench run.
/// Always present in BENCH_pipeline.json (zeroed when skipped) so CI
/// can assert the schema even on artifact-less runners. NOTE: misses
/// count gather-path stalls only — prefetch-satisfied fetches bypass
/// the miss counter by design — so "the cache was exercised" is
/// `hits + misses > 0`, never `misses > 0`.
fn cache_doc(hits: f64, misses: f64, evictions: f64) -> Value {
    obj(vec![
        ("hits", num(hits)),
        ("misses", num(misses)),
        ("evictions", num(evictions)),
    ])
}

/// The `rho serve` record: tenant count, aggregate steps/sec across
/// the time-sliced two-tenant run, and the fairness imbalance — the
/// worst per-tenant |pick share − weight share| observed while both
/// tenants contended for slices (DRR bounds this by ~1/contended
/// rounds). Always present in BENCH_pipeline.json (zeroed when
/// skipped) so tooling can rely on the schema.
fn serve_doc(tenants: f64, steps_per_sec: f64, imbalance: f64, per_tenant: Value) -> Value {
    obj(vec![
        ("tenants", num(tenants)),
        ("steps_per_sec", num(steps_per_sec)),
        ("imbalance", num(imbalance)),
        ("per_tenant", per_tenant),
    ])
}

fn main() {
    let smoke = std::env::var("RHO_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    println!("== bench_pipeline{} ==", if smoke { " (smoke)" } else { "" });
    let ctx = ExpCtx::new(if smoke { 0.05 } else { 0.25 });
    if !ctx.artifacts.join("manifest.json").exists() {
        println!("(artifacts missing: run `make artifacts`)");
        // The skipped record still carries the overlap schema so CI
        // can assert the fields exist even on artifact-less runners.
        write_doc(obj(vec![
            ("bench", s("pipeline")),
            ("skipped", Value::Bool(true)),
            ("reason", s("artifact manifest missing")),
            ("speculate", speculate_axis()),
            ("overlap", overlap_doc(0.0, 0.0, 0.0, 0.0, 0.0, 0)),
            ("cache", cache_doc(0.0, 0.0, 0.0)),
            ("serve", serve_doc(0.0, 0.0, 0.0, Value::Array(Vec::new()))),
        ]));
        return;
    }
    let lab = Lab::new(&ctx).unwrap();
    let base = RunConfig {
        dataset: "cifar10".into(),
        arch: "mlp_base".into(),
        il_arch: "mlp_small".into(),
        method: Method::RhoLoss,
        epochs: if smoke { 1 } else { 3 },
        il_epochs: if smoke { 1 } else { 4 },
        ..Default::default()
    };
    let worker_sweep: &[usize] = if smoke { &[1] } else { &[1, 4] };
    let bundle = lab.bundle(&base.dataset);
    let target = lab.runtime(&base.arch, &base.dataset).unwrap();
    let (d, c) = rho::data::catalog::dims_for(&base.dataset);
    let fwd = lab.manifest.find(&base.arch, d, c, "fwd_b320").unwrap();
    let sel = lab.manifest.find(&base.arch, d, c, "select_b320").unwrap();

    let mut sync_by_method = std::collections::HashMap::new();
    let mut entries: Vec<Value> = Vec::new();
    for method in [Method::Uniform, Method::TrainLoss, Method::RhoLoss] {
        let mut cfg = base.clone();
        cfg.method = method;
        let il: Option<std::rc::Rc<IlContext>> = if method.needs_il() {
            Some(lab.il_context(&cfg, &bundle).unwrap())
        } else {
            None
        };
        let il_ref = il.as_deref();

        let sync = Session::new(&cfg, &target).run(&bundle, il_ref).unwrap();
        let sync_sps = sync.steps_per_sec();
        sync_by_method.insert(method, sync_sps);
        println!("{:<12} inline:             {sync_sps:>7.1} steps/s", method.name());
        entries.push(obj(vec![
            ("method", s(method.name())),
            ("source", s("memory")),
            ("workers", num(0.0)), // 0 = inline reference
            ("steps_per_sec", num(sync_sps)),
        ]));

        for &workers in worker_sweep {
            let pool = ScoringPool::new(
                fwd,
                sel,
                None,
                &PoolConfig { workers, lane_depth: 16, ..PoolConfig::default() },
            )
            .unwrap();
            let plane = ComputePlane::new("target", base.arch.clone(), Rc::new(pool));
            let res = Session::new(&cfg, &target)
                .plane(&plane)
                .prefetch(4)
                .run(&bundle, il_ref)
                .unwrap();
            let sps = res.steps_per_sec();
            let t = res.plane_timings.first().cloned().unwrap_or_default();
            println!(
                "{:<12} plane workers={workers}:   {sps:>7.1} steps/s ({:+.0}% vs inline, queue-wait {:.0}us/chunk)",
                method.name(),
                (sps / sync_sps - 1.0) * 100.0,
                t.mean_queue_wait_us
            );
            entries.push(obj(vec![
                ("method", s(method.name())),
                ("source", s("memory")),
                ("workers", num(workers as f64)),
                ("steps_per_sec", num(sps)),
                ("vs_sync_pct", num((sps / sync_sps - 1.0) * 100.0)),
                ("plane", s(&t.plane)),
                ("dispatches", num(t.dispatches as f64)),
                ("chunks", num(t.chunks as f64)),
                ("mean_queue_wait_us", num(t.mean_queue_wait_us)),
                ("mean_busy_us", num(t.mean_busy_us)),
                ("inflight_s", num(t.inflight_s)),
                ("overlap_s", num(t.overlap_s)),
                ("worker_chunks", arr(t.worker_chunks.iter().map(|&ch| num(ch as f64)))),
                ("worker_rates", arr(t.worker_rates.iter().map(|&r| num(r)))),
                // supervision: all-zero / all-"live" on a healthy run,
                // but the schema is always present so perf tooling can
                // discard degraded measurements (a recovered run's
                // steps/sec is not comparable to a healthy one's)
                ("recovered_chunks", num(t.recovered_chunks as f64)),
                ("worker_deaths", num(t.worker_deaths as f64)),
                ("respawns", num(t.respawns as f64)),
                ("deadline_expiries", num(t.deadline_expiries as f64)),
                ("worker_health", arr(t.worker_health.iter().map(|h| s(h)))),
            ]));
        }
    }

    // --- cross-plane overlap: rho_loss + online_il -------------------
    // The §3 economics lever the two-phase dispatch API opens: with
    // track_props on, the stack is [OnlineIl(il plane), FwdStats
    // (target plane)] and BOTH fwds submit before either resolves, so
    // the cheap IL fwd is in flight concurrently with the expensive
    // target fwd for the same batch (the fused-RHO variant serializes
    // on its il-signal data dependency; `select` falls back to
    // loss − il here). The run is swept over speculate ∈ {0, 1}: the
    // speculative leg additionally submits batch t+1's target fwd
    // before step t's gradient update, so `train_overlap_s` (scoring
    // wall-clock under an open train step) goes >0 only at
    // speculate=1. The per-step overlap metric below is the acceptance
    // headline: >0 means the target-plane and il-plane forwards
    // genuinely ran concurrently.
    let overlap = {
        let mut cfg = base.clone();
        cfg.method = Method::RhoLoss;
        cfg.online_il = true;
        cfg.track_props = true;
        let il = lab.il_context(&cfg, &bundle).unwrap();
        let il_rt = lab.runtime(&cfg.il_arch, &cfg.dataset).unwrap();
        let workers = if smoke { 1 } else { 2 };
        let pc = PoolConfig { workers, lane_depth: 16, ..PoolConfig::default() };
        let ifwd = lab.manifest.find(&cfg.il_arch, d, c, "fwd_b320").unwrap();
        let isel = lab.manifest.find(&cfg.il_arch, d, c, "select_b320").unwrap();
        let mut headline = overlap_doc(0.0, 0.0, 0.0, 0.0, 0.0, 0);
        for speculate in [false, true] {
            // Fresh pools per sweep point so worker threads and ledger
            // counters start cold for both settings.
            let t_pool = ScoringPool::new(fwd, sel, None, &pc).unwrap();
            let target_plane =
                ComputePlane::new("target", base.arch.clone(), Rc::new(t_pool));
            let i_pool = ScoringPool::new(ifwd, isel, None, &pc).unwrap();
            let il_plane = ComputePlane::new("il", cfg.il_arch.clone(), Rc::new(i_pool));
            let res = Session::new(&cfg, &target)
                .il_runtime(&il_rt)
                .plane(&target_plane)
                .plane(&il_plane)
                .prefetch(4)
                .speculate(speculate)
                .run(&bundle, Some(&il))
                .unwrap();
            let sps = res.steps_per_sec();
            let by_plane = |name: &str| {
                res.plane_timings.iter().find(|t| t.plane == name).cloned().unwrap_or_default()
            };
            let (tp, ip) = (by_plane("target"), by_plane("il"));
            println!(
                "rho_loss+online_il 2-plane speculate={}: {sps:>7.1} steps/s, overlap \
                 {:.2}ms/step, over-train {:.2}s, spec-hit {:.0}% \
                 (target in-flight {:.2}s ∥ il in-flight {:.2}s over {} steps)",
                speculate as u8,
                res.overlap_s_per_step() * 1e3,
                res.train_overlap_s(),
                res.spec_hit_ratio() * 100.0,
                tp.inflight_s,
                ip.inflight_s,
                res.steps
            );
            entries.push(obj(vec![
                ("method", s("rho_loss")),
                ("online_il", Value::Bool(true)),
                ("source", s("memory")),
                ("workers", num(workers as f64)),
                ("speculate", num(speculate as u8 as f64)),
                ("steps_per_sec", num(sps)),
                ("plane", s("target+il")),
                ("inflight_s", num(tp.inflight_s + ip.inflight_s)),
                ("overlap_s", num(res.cross_plane_overlap_s())),
                ("overlap_s_per_step", num(res.overlap_s_per_step())),
                ("train_overlap_s", num(res.train_overlap_s())),
                ("spec_hit_ratio", num(res.spec_hit_ratio())),
                ("accepted_stale", num(res.accepted_stale as f64)),
            ]));
            headline = overlap_doc(
                tp.inflight_s,
                ip.inflight_s,
                res.cross_plane_overlap_s(),
                res.overlap_s_per_step(),
                res.train_overlap_s(),
                res.steps,
            );
        }
        headline
    };

    // --- source=shards axis: the on-disk data plane ------------------
    // Ingest the bundle once (measuring bytes/sec), write IL sidecars
    // straight from the amortized IL table, then stream the same runs
    // from the mmap'd store. At workers=1 the curves are bitwise the
    // memory curves (tests/store_integration.rs); here we record what
    // the substrate swap costs in steps/sec.
    let store_dir =
        std::env::temp_dir().join(format!("rho-bench-store-{}", std::process::id()));
    std::fs::remove_dir_all(&store_dir).ok();
    let ingest_sw = rho::util::timer::Stopwatch::start();
    let report = rho::data::store::ingest_bundle(&bundle, &store_dir, 1024).unwrap();
    let ingest_secs = ingest_sw.elapsed_s();
    let ingest_bps = if ingest_secs > 0.0 { report.total_bytes() as f64 / ingest_secs } else { 0.0 };
    println!(
        "ingest: {} rows, {:.1} MiB at {:.0} MiB/s -> {}",
        report.total_rows(),
        report.total_bytes() as f64 / (1024.0 * 1024.0),
        ingest_bps / (1024.0 * 1024.0),
        store_dir.display()
    );
    {
        // sidecars from the already-computed IL table (score-il's output
        // bytes, without re-measuring IL training here)
        let mut rho_cfg = base.clone();
        rho_cfg.method = Method::RhoLoss;
        let il = lab.il_context(&rho_cfg, &bundle).unwrap();
        let store = rho::data::store::ShardStore::open(&store_dir).unwrap();
        let mut off = 0usize;
        for shard in store.train.shards() {
            rho::data::store::write_sidecar(&shard.path, &il.values[off..off + shard.rows])
                .unwrap();
            off += shard.rows;
        }
    }
    let shard_workers: Vec<usize> = if smoke { vec![0] } else { vec![0, 4] };
    for method in [Method::Uniform, Method::RhoLoss] {
        for &workers in &shard_workers {
            let mut cfg = base.clone();
            cfg.method = method;
            cfg.workers = workers;
            cfg.source = format!("shards://{}", store_dir.display());
            let res = lab.run_auto(&cfg).unwrap();
            let sps = res.steps_per_sec();
            let vs = sync_by_method.get(&method).copied().unwrap_or(0.0);
            println!(
                "{:<12} shards workers={workers}:  {sps:>7.1} steps/s ({:+.0}% vs memory inline)",
                method.name(),
                if vs > 0.0 { (sps / vs - 1.0) * 100.0 } else { 0.0 }
            );
            entries.push(obj(vec![
                ("method", s(method.name())),
                ("source", s("shards")),
                ("workers", num(workers as f64)),
                ("steps_per_sec", num(sps)),
            ]));
        }
    }

    // --- source=remote axis: the HTTP shard plane --------------------
    // Serve the same store over loopback with the in-repo range server
    // and stream the runs through a bounded LRU cache sized at half
    // the train split, so eviction is live during the walk. Cache
    // counters are recorded per entry as deltas (the RemoteStore — and
    // its counters — persists across runs at the same url+cap).
    let cache = {
        let server = rho::data::store::TestServer::serve(&store_dir).unwrap();
        let train_bytes = rho::data::store::StoreManifest::load(&store_dir)
            .unwrap()
            .split("train")
            .unwrap()
            .bytes();
        let mut rem = base.clone();
        rem.source = server.url();
        rem.cache_bytes = train_bytes / 2;
        let store = lab.remote(&rem).unwrap();
        for method in [Method::Uniform, Method::RhoLoss] {
            for &workers in &shard_workers {
                let mut cfg = rem.clone();
                cfg.method = method;
                cfg.workers = workers;
                let before = store.cache_stats();
                let res = lab.run_auto(&cfg).unwrap();
                let after = store.cache_stats();
                let sps = res.steps_per_sec();
                let vs = sync_by_method.get(&method).copied().unwrap_or(0.0);
                println!(
                    "{:<12} remote workers={workers}:  {sps:>7.1} steps/s ({:+.0}% vs memory \
                     inline, cache {}h/{}m/{}e)",
                    method.name(),
                    if vs > 0.0 { (sps / vs - 1.0) * 100.0 } else { 0.0 },
                    after.hits - before.hits,
                    after.misses - before.misses,
                    after.evictions - before.evictions
                );
                entries.push(obj(vec![
                    ("method", s(method.name())),
                    ("source", s("remote")),
                    ("workers", num(workers as f64)),
                    ("steps_per_sec", num(sps)),
                    ("cache_hits", num((after.hits - before.hits) as f64)),
                    ("cache_misses", num((after.misses - before.misses) as f64)),
                    ("cache_evictions", num((after.evictions - before.evictions) as f64)),
                ]));
            }
        }
        let settled = store.cache_stats();
        println!(
            "remote cache (cap {:.1} MiB): {} hits, {} misses, {} evictions settled",
            rem.cache_bytes as f64 / (1024.0 * 1024.0),
            settled.hits,
            settled.misses,
            settled.evictions
        );
        cache_doc(settled.hits as f64, settled.misses as f64, settled.evictions as f64)
    };
    std::fs::remove_dir_all(&store_dir).ok();

    // --- serve axis: two tenants time-sliced over one shared pool ----
    // The multi-session scheduler's cost model: aggregate steps/sec
    // for two weighted tenants sliced over a single PlaneKey-shared
    // pool, plus the DRR fairness imbalance observed while both
    // contended. A fresh Lab keeps the served pool registry cold, the
    // same start state as a fresh `rho serve` daemon.
    let serve = {
        use rho::coordinator::scheduler::Daemon;
        use rho::experiments::common::ServedLab;
        let mut sbase = base.clone();
        sbase.method = Method::RhoLoss;
        sbase.workers = if smoke { 1 } else { 4 };
        sbase.serve_slice_steps = if smoke { 8 } else { 16 };
        sbase.serve_max_sessions = 4;
        sbase.serve_dir = std::env::temp_dir()
            .join(format!("rho-bench-serve-{}", std::process::id()))
            .display()
            .to_string();
        let weights: &[(&str, f64)] = &[("a", 2.0), ("b", 1.0)];
        let mut d =
            Daemon::new(sbase.clone(), ServedLab::new(Lab::new(&ctx).unwrap(), sbase.workers));
        for (i, (id, w)) in weights.iter().enumerate() {
            d.submit(id, *w, &[("seed".to_string(), (i + 1).to_string())]).unwrap();
        }
        let sw = rho::util::timer::Stopwatch::start();
        let mut picks: std::collections::HashMap<String, u64> = Default::default();
        let mut contended = 0u64;
        while d.runnable() > 1 {
            if let Some(id) = d.tick() {
                *picks.entry(id).or_default() += 1;
                contended += 1;
            }
        }
        while d.runnable() > 0 {
            d.tick();
        }
        let secs = sw.elapsed_s();
        let rows = d.status(None);
        let total_steps: u64 = rows.iter().map(|r| r.steps).sum();
        let total_w: f64 = weights.iter().map(|(_, w)| w).sum();
        let imbalance = if contended == 0 {
            0.0
        } else {
            weights
                .iter()
                .map(|(id, w)| {
                    let share = *picks.get(*id).unwrap_or(&0) as f64 / contended as f64;
                    (share - w / total_w).abs()
                })
                .fold(0.0, f64::max)
        };
        let sps = if secs > 0.0 { total_steps as f64 / secs } else { 0.0 };
        println!(
            "serve {} tenants (weights 2:1): {sps:>7.1} steps/s aggregate, fairness \
             imbalance {imbalance:.3} over {contended} contended slices",
            rows.len()
        );
        std::fs::remove_dir_all(&sbase.serve_dir).ok();
        serve_doc(
            rows.len() as f64,
            sps,
            imbalance,
            Value::Array(
                rows.iter()
                    .map(|r| {
                        obj(vec![
                            ("tenant", s(&r.tenant)),
                            ("steps", num(r.steps as f64)),
                            ("slices", num(r.slices as f64)),
                            ("train_secs", num(r.train_secs)),
                        ])
                    })
                    .collect(),
            ),
        )
    };

    // Selection-overhead ratio (paper §3: the selection fwd pass costs
    // n_B/(3 n_b) of a train step in theory), from the inline runs.
    let uni_sps = sync_by_method[&Method::Uniform];
    let rho_sps = sync_by_method[&Method::RhoLoss];
    println!(
        "uniform/rho inline ratio: {:.2}x (paper theory ~{:.2}x fwd-only)",
        uni_sps / rho_sps,
        1.0 + 320.0 / (3.0 * 32.0)
    );

    // Machine-readable perf record (steps/sec per method × workers ×
    // source, plus the shard-ingest throughput).
    write_doc(obj(vec![
        ("bench", s("pipeline")),
        ("smoke", Value::Bool(smoke)),
        ("scale", num(ctx.scale)),
        ("epochs", num(base.epochs as f64)),
        ("uniform_over_rho_sync", num(uni_sps / rho_sps)),
        ("ingest_bytes_per_sec", num(ingest_bps)),
        ("ingest_rows", num(report.total_rows() as f64)),
        ("speculate", speculate_axis()),
        ("overlap", overlap),
        ("cache", cache),
        ("serve", serve),
        ("entries", Value::Array(entries)),
    ]));
}
