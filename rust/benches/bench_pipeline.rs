//! End-to-end pipeline throughput (steps/sec): synchronous Algorithm-1
//! trainer vs the streaming pipelined trainer at 1/2/4 scoring
//! workers. This regenerates the paper's §3 parallelized-selection
//! claim at bench scale and is the primary L3 perf target
//! (EXPERIMENTS.md §Perf).

use rho::config::RunConfig;
use rho::coordinator::pipeline::run_pipelined;
use rho::coordinator::trainer::Trainer;
use rho::experiments::common::Lab;
use rho::experiments::ExpCtx;
use rho::runtime::pool::{PoolConfig, ScoringPool};
use rho::selection::Method;
use rho::util::timer::Stopwatch;

fn main() {
    println!("== bench_pipeline ==");
    let ctx = ExpCtx::new(0.25);
    if !ctx.artifacts.join("manifest.json").exists() {
        println!("(artifacts missing: run `make artifacts`)");
        return;
    }
    let lab = Lab::new(&ctx).unwrap();
    let cfg = RunConfig {
        dataset: "cifar10".into(),
        arch: "mlp_base".into(),
        il_arch: "mlp_small".into(),
        method: Method::RhoLoss,
        epochs: 3,
        il_epochs: 4,
        ..Default::default()
    };
    let bundle = lab.bundle(&cfg.dataset);
    let target = lab.runtime(&cfg.arch, &cfg.dataset).unwrap();
    let il = lab.il_context(&cfg, &bundle).unwrap();

    let sw = Stopwatch::start();
    let sync = Trainer::new(&cfg, &target).run(&bundle, Some(&il)).unwrap();
    let sync_sps = sync.steps as f64 / sw.elapsed_s();
    println!("sync trainer:        {sync_sps:>7.1} steps/s");

    let (d, c) = rho::data::catalog::dims_for(&cfg.dataset);
    let fwd = lab.manifest.find(&cfg.arch, d, c, "fwd_b320").unwrap();
    let sel = lab.manifest.find(&cfg.arch, d, c, "select_b320").unwrap();
    for workers in [1usize, 2, 4] {
        let pool =
            ScoringPool::new(fwd, sel, &PoolConfig { workers, queue_depth: 16 }).unwrap();
        let (_, sps) = run_pipelined(&cfg, &target, &pool, &bundle, &il, 4).unwrap();
        println!(
            "pipelined workers={workers}: {sps:>7.1} steps/s ({:+.0}% vs sync)",
            (sps / sync_sps - 1.0) * 100.0
        );
    }

    // Uniform trainer for the selection-overhead ratio (paper §3: the
    // selection fwd pass costs n_B/(3 n_b) of a train step in theory).
    let mut ucfg = cfg.clone();
    ucfg.method = Method::Uniform;
    let sw = Stopwatch::start();
    let uni = Trainer::new(&ucfg, &target).run(&bundle, None).unwrap();
    let uni_sps = uni.steps as f64 / sw.elapsed_s();
    println!(
        "uniform trainer:     {uni_sps:>7.1} steps/s (selection overhead {:.2}x; paper theory ~{:.2}x fwd-only)",
        uni_sps / sync_sps,
        1.0 + 320.0 / (3.0 * 32.0)
    );
}
