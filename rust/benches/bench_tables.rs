//! One bench per paper table/figure: runs every experiment harness at
//! bench scale and reports wall time. This is the "regenerate the
//! whole evaluation" entry point — the same code paths as
//! `rho exp all`, shrunk to keep `cargo bench` minutes-scale.
//!
//! Full-scale reproduction: `rho exp all --scale 0.3 --seeds 1,2`
//! (see EXPERIMENTS.md for recorded results).

use rho::experiments::{self, ExpCtx};
use rho::util::timer::Stopwatch;

fn main() {
    println!("== bench_tables: every paper table/figure at bench scale ==");
    let mut ctx = ExpCtx::new(0.06);
    ctx.epoch_scale = 0.2;
    ctx.seeds = vec![1];
    ctx.results = std::path::PathBuf::from("results/bench");
    if !ctx.artifacts.join("manifest.json").exists() {
        println!("(artifacts missing: run `make artifacts`)");
        return;
    }
    let mut failed = 0;
    for id in experiments::ALL {
        let sw = Stopwatch::start();
        match experiments::run(id, &ctx) {
            Ok(()) => println!("[bench {id:<8}] {:>6.1}s OK", sw.elapsed_s()),
            Err(e) => {
                failed += 1;
                println!("[bench {id:<8}] {:>6.1}s FAILED: {e:#}", sw.elapsed_s());
            }
        }
    }
    if failed > 0 {
        std::process::exit(1);
    }
}
